"""Gateway: the single serving entry point over both drivers.

``Gateway(backend="runtime")`` wraps the real threaded ``SageRuntime``
(or a ``ClusterRuntime`` when ``n_nodes > 1``); ``backend="sim"`` wraps the
virtual-time ``Simulator`` twin. Registration takes a
:class:`~repro.api.spec.FunctionSpec`, load comes from
``invoke``/``invoke_async``/``replay(workload)``, and ``report()`` returns
the one shared :class:`~repro.core.telemetry.Telemetry` — so any workload
can be replayed against both backends and their records compared 1:1
(tests/test_api.py holds that parity contract).

The mechanism layer stays importable and unchanged: ``gateway.runtime`` /
``gateway.sim`` expose the wrapped driver for tooling that needs to peek at
daemons, engines, or brokers.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.api.spec import FunctionSpec
from repro.api.workload import Arrival, Workload
from repro.core.dispatch import DISPATCH_POLICIES, choose_node
from repro.core.faults import (
    BreakerConfig,
    BreakerOpenError,
    CircuitBreaker,
    DbFlap,
    FaultPlan,
    LinkDegradation,
    MemoryLeak,
    NodeCrash,
    NodeLostError,
    ShedError,
    SlowNode,
    classify_error,
    SheddingConfig,
    node_pressure,
)
from repro.core.profiles import MB
from repro.core.slowness import (
    QuarantineController,
    make_detector,
    resolve_hedging,
    resolve_quarantine,
)
from repro.core.telemetry import InvocationRecord, Telemetry
from repro.core.transfer import TRANSFER_MODES

DEFAULT_INPUT_BYTES = 4 * MB
# MemoryLeak tick granularity in workload seconds (sim twin parity:
# simulator._LEAK_TICK_S) — each tick injects rate_bps * tick bytes
_LEAK_TICK_S = 0.5
# per-invocation completion deadline for runtime-backend replay (the
# wall-clock analogue of the old hand-rolled future.result(timeout=...))
DEFAULT_REPLAY_TIMEOUT_S = 300.0

_BACKENDS = ("runtime", "sim")


class Invocation:
    """Handle for one in-flight invocation.

    ``wait()`` blocks (real time or virtual time) and returns the
    invocation's :class:`InvocationRecord`. With ``strict=True`` (default)
    a failed invocation raises instead; with ``strict=False`` the failure
    stays in ``record.error`` / ``Telemetry.errors()`` and the record is
    returned.
    """

    def wait(self, timeout: Optional[float] = None, *,
             strict: bool = True) -> InvocationRecord:
        raise NotImplementedError

    def result(self, timeout: Optional[float] = None, *,
               strict: bool = True) -> InvocationRecord:
        return self.wait(timeout, strict=strict)


class _RuntimeInvocation(Invocation):
    def __init__(self, node, future, request_uuid: str):
        self._node = node
        self._future = future
        self._uuid = request_uuid

    def wait(self, timeout=None, *, strict=True):
        exc: Optional[BaseException] = None
        try:
            self._future.result(timeout=timeout)
        except BaseException as e:  # recorded in telemetry either way
            exc = e
        rec = self._node.telemetry.find(self._uuid)
        if exc is not None and strict:
            raise exc
        if rec is None:
            # non-strict only swallows failures that produced a record
            # (a wait timeout has nothing to return)
            if exc is not None:
                raise exc
            raise RuntimeError(f"no record for invocation {self._uuid}")
        return rec


class _RejectedInvocation(Invocation):
    """Handle for a request the control layer refused before submission
    (shed or breaker-open). The rejection is already recorded; ``wait``
    returns instantly — strict mode raises the matching typed error."""

    def __init__(self, rec: InvocationRecord):
        self._rec = rec

    def wait(self, timeout=None, *, strict=True):
        if strict:
            exc = (ShedError if self._rec.error_class == "shed"
                   else BreakerOpenError)
            raise exc(self._rec.error)
        return self._rec


class _ResilientInvocation(Invocation):
    """Runtime handle with the resilience control loop attached: feeds the
    function's circuit breaker with the final outcome and — when eviction
    is on — re-dispatches a :class:`NodeLostError` failure to a healthy
    node within the request's ``max_retries`` budget (None = unlimited
    while healthy nodes remain, 0 = fail fast). Superseded attempts'
    records are marked ``dropped`` so merged telemetry counts ONE outcome
    per request with exact accounting (docs/resilience.md)."""

    def __init__(self, gw: "Gateway", name: str, node_idx: int, req,
                 future, *, seed: int, input_bytes: int):
        self._gw = gw
        self._name = name
        self._node_idx = node_idx
        self._req = req
        self._seed = seed
        self._input_bytes = input_bytes
        self._redispatches = 0
        self._done = threading.Event()
        self._rec: Optional[InvocationRecord] = None
        self._exc: Optional[BaseException] = None
        # hedged redispatch state (docs/resilience.md): at most one
        # speculative twin per logical request; first completion wins
        self._hlock = threading.Lock()
        self._settled = False
        self._pending = {req.uuid}
        self._hedge: Optional[Tuple[int, object, float]] = None
        self._hedge_timer: Optional[threading.Timer] = None
        self._t_start = time.monotonic()
        future.add_done_callback(
            lambda f: self._on_done(f, node_idx, req, False))
        self._arm_hedge()

    # -- hedged redispatch ---------------------------------------------
    def _arm_hedge(self) -> None:
        """Start the hedge timer at the function's learned latency
        quantile; no-op until the detector has enough samples."""
        gw = self._gw
        if gw._hedging is None or gw._slowness is None \
                or not gw.policy.startswith("sage"):
            return
        with gw._tail_lock:
            est = gw._slowness.estimate(self._name, gw._hedging.min_samples)
        if est is None:
            return
        tm = threading.Timer(est * gw._hedging.delay_factor,
                             self._hedge_fire)
        tm.daemon = True
        self._hedge_timer = tm
        tm.start()

    def _hedge_fire(self) -> None:
        """The invocation outlived its latency estimate: launch ONE
        speculative duplicate on the best non-suspect node (charged to
        the request's ``max_retries`` budget, like a crash re-dispatch)."""
        gw = self._gw
        with self._hlock:
            if self._settled or self._hedge is not None:
                return
            budget = self._req.max_retries
            if budget is not None and self._redispatches >= budget:
                return
        with gw._tail_lock:
            suspects = set(gw._slowness.suspects())
            scores = {n.node_id: gw._slowness.health_score(n.node_id)
                      for n in gw._nodes}
        primary_id = gw._nodes[self._node_idx].node_id
        cands = [i for i, n in enumerate(gw._nodes)
                 if n.healthy and not (n.draining or n.retired)
                 and n.node_id != primary_id
                 and n.node_id not in suspects]
        if not cands:
            return
        snaps = [gw._nodes[i].dispatch_snapshot(
            self._name, health_score=scores[gw._nodes[i].node_id])
            for i in cands]
        pick = choose_node("locality", snaps)
        idx = cands[pick]
        req2 = gw._build_request(
            self._name, idx, seed=self._seed, input_bytes=self._input_bytes,
            deadline_s=self._req.deadline_s, priority=self._req.priority,
            max_retries=self._req.max_retries,
            dispatch_tier=snaps[pick].ro_tier)
        req2.arrival_t = self._req.arrival_t  # same logical arrival
        with self._hlock:
            if self._settled:
                return
            self._redispatches += 1
            req2.redispatches = self._redispatches
            # cooperative cancel tokens for BOTH twins: whichever loses
            # aborts at its next engine checkpoint and unwinds byte-exactly
            self._req.hedge_cancel = threading.Event()
            req2.hedge_cancel = threading.Event()
            self._hedge = (idx, req2, time.monotonic())
            self._pending.add(req2.uuid)
        try:
            fut = gw._nodes[idx].submit(req2)
        except RuntimeError:
            # the timer raced a pool shutdown: unwind — the primary
            # remains the request's only attempt
            with self._hlock:
                self._pending.discard(req2.uuid)
                self._hedge = None
                self._redispatches -= 1
            return
        gw._redispatches += 1
        with gw._tail_lock:
            gw._hedges_launched += 1
        fut.add_done_callback(
            lambda f: self._on_done(f, idx, req2, True))

    # -- control loop (runs on the pool thread that finished the attempt)
    def _on_done(self, future, node_idx: int, req, is_hedge: bool) -> None:
        gw = self._gw
        exc = future.exception()
        rec = gw._nodes[node_idx].telemetry.find(req.uuid)
        with self._hlock:
            self._pending.discard(req.uuid)
            paired = self._hedge is not None
            if paired:
                if self._settled:
                    win = False          # the race was already decided
                elif exc is None or not self._pending:
                    # success — or the last twin standing (even a failure
                    # is the request's one outcome once its twin is gone)
                    self._settled = True
                    win = True
                else:
                    win = False          # failed while the twin still runs
        if paired:
            if win:
                self._win(rec, exc, node_idx, req, is_hedge)
            else:
                self._drop_loser(rec, exc)
            return
        # -- unpaired: the seed crash-re-dispatch control loop ----------
        if isinstance(exc, NodeLostError) and gw._evict:
            budget = self._req.max_retries
            healthy = [i for i, n in enumerate(gw._nodes)
                       if n.healthy and not (n.draining or n.retired)]
            if healthy and (budget is None or self._redispatches < budget):
                # supersede this attempt's record — the re-dispatch is the
                # same logical request, not a second outcome
                if rec is not None:
                    rec.dropped = True
                self._redispatches += 1
                gw._redispatches += 1
                try:
                    self._resubmit(healthy)
                    return
                except Exception as e:  # re-dispatch itself failed
                    exc, rec = e, rec if rec is not None else None
        self._finalize(rec, exc)

    def _win(self, rec, exc, node_idx: int, req, is_hedge: bool) -> None:
        """This attempt decides the request: cancel the loser twin, feed
        its censored elapsed time to the detector (a cancelled straggler
        never completes — without this the evidence starves), count the
        hedge outcome, and finalize."""
        gw = self._gw
        if self._hedge_timer is not None:
            self._hedge_timer.cancel()
        with self._hlock:
            loser_alive = bool(self._pending)
        if loser_alive:
            if is_hedge:
                lidx, lreq, lt0 = self._node_idx, self._req, self._t_start
            else:
                lidx, lreq, lt0 = self._hedge
            if lreq.hedge_cancel is not None:
                lreq.hedge_cancel.set()
            loser_node = gw._nodes[lidx]
            elapsed = time.monotonic() - lt0
            with gw._tail_lock:
                gw._slowness.observe(loser_node.node_id, "compute", elapsed)
            gw._quarantine_note(loser_node.node_id, elapsed)
        if exc is None:
            with gw._tail_lock:
                if is_hedge:
                    gw._hedges_won += 1
                else:
                    gw._hedges_wasted += 1
        self._node_idx, self._req = node_idx, req
        self._finalize(rec, exc)

    def _drop_loser(self, rec, exc) -> None:
        """A superseded twin landed (cancelled at a checkpoint, failed,
        or finished late): mark its record dropped/"hedged" — never a
        second outcome, never a breaker feed (sim parity)."""
        if rec is not None:
            rec.dropped = True
            rec.redispatches = self._redispatches
            if rec.error is None:
                rec.error = (f"HedgedError: {self._name}: "
                             "superseded by hedged twin")
            if rec.error_class is None:
                rec.error_class = (
                    "hedged" if rec.error.startswith("HedgedError")
                    else classify_error(rec.error))
        if isinstance(exc, NodeLostError):
            self._gw._node_lost += 1

    def _resubmit(self, healthy: List[int]) -> None:
        gw, name = self._gw, self._name
        if len(healthy) == len(gw._nodes):
            idx, tier = gw._pick_node(name)
        elif gw.runtime is not None and hasattr(gw.runtime, "select_node"):
            idx, tier = gw.runtime.select_node(name)
        else:
            idx, tier = healthy[0], None
        req = gw._build_request(
            name, idx, seed=self._seed, input_bytes=self._input_bytes,
            deadline_s=self._req.deadline_s, priority=self._req.priority,
            max_retries=self._req.max_retries, dispatch_tier=tier)
        # the logical arrival time spans attempts: latency is measured
        # arrival-to-final-finish, like the simulator's re-dispatch path
        req.arrival_t = self._req.arrival_t
        req.fault_injected = False  # the draw was consumed by attempt #1
        self._node_idx, self._req = idx, req
        with self._hlock:
            self._pending.add(req.uuid)
        gw._nodes[idx].submit(req).add_done_callback(
            lambda f: self._on_done(f, idx, req, False))

    def _finalize(self, rec, exc) -> None:
        with self._hlock:
            # the race is decided on EVERY path (an unpaired completion
            # included) — a hedge timer that fires later must see settled
            # and stand down instead of hedging a finished request
            self._settled = True
        if self._hedge_timer is not None:
            self._hedge_timer.cancel()
        if rec is not None:
            rec.redispatches = self._redispatches
            if rec.error_class is None and rec.error is not None:
                # stamp the class like the sim driver does, so per-record
                # consumers need no classify_error fallback
                rec.error_class = classify_error(rec.error)
        if exc is None and rec is not None and self._gw._slowness is not None:
            # detector feed (the sim's _tail_complete call site): one
            # successful outcome per request grades its node
            self._gw._tail_observe(
                self._gw._nodes[self._node_idx].node_id, rec)
        self._gw._note_result(self._name, exc is None)
        if isinstance(exc, NodeLostError):
            self._gw._node_lost += 1
        self._rec, self._exc = rec, exc
        self._done.set()

    # -- Invocation interface ------------------------------------------
    def wait(self, timeout=None, *, strict=True):
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"invocation {self._req.uuid} still in flight")
        if self._exc is not None and strict:
            raise self._exc
        if self._rec is None:
            if self._exc is not None:
                raise self._exc
            raise RuntimeError(f"no record for invocation {self._req.uuid}")
        return self._rec


class _SimInvocation(Invocation):
    def __init__(self, sim, request_id: str):
        self._sim = sim
        self._rid = request_id

    def wait(self, timeout=None, *, strict=True):
        # ``timeout`` is accepted for interface parity; virtual time drains
        # instantly, so there is nothing wall-clock to bound here
        rec = self._sim.telemetry.find(self._rid)
        if rec is None:
            self._sim.run()  # drain virtual time
            rec = self._sim.telemetry.find(self._rid)
        if rec is None:
            raise RuntimeError(
                f"simulated invocation {self._rid} never completed")
        if strict and rec.error is not None:
            # control-layer rejections raise the same typed errors the
            # runtime backend raises (tests assert on the type)
            exc = {"shed": ShedError,
                   "breaker": BreakerOpenError}.get(rec.error_class,
                                                    RuntimeError)
            raise exc(rec.error)
        return rec


class Gateway:
    """One serving API over the real runtime and the simulator twin."""

    def __init__(self, backend: str = "sim", policy: str = "sage", *,
                 n_nodes: int = 1, device_capacity: int = 40 << 30,
                 host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, seed: int = 0,
                 time_scale: float = 1.0, loader_threads: int = 4,
                 load_timeout_s: Optional[float] = None,
                 max_workers: int = 32, serialize_compute: bool = True,
                 scheduler: Optional[str] = None,
                 dispatch: Optional[str] = None,
                 transfer: Optional[str] = None,
                 chunk_bytes: Optional[int] = None,
                 faults: Optional[FaultPlan] = None,
                 breaker: Optional[BreakerConfig] = None,
                 shedding: Optional[SheddingConfig] = None,
                 eviction: bool = False,
                 autoscale=None,
                 hedging=None,
                 quarantine=None,
                 compute=None):
        if backend not in _BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use one of {_BACKENDS}")
        self.backend = backend
        self.policy = policy
        self.specs: Dict[str, FunctionSpec] = {}
        self._seq = itertools.count()
        self.sim = None
        self.runtime = None
        # resilience layer (docs/resilience.md): the sim backend owns its
        # own copy of these knobs; the runtime backend gates at the gateway
        # so the control decisions sit in front of node dispatch on BOTH
        # drivers, in the same order (draw -> shed -> breaker -> dispatch)
        self.faults = faults
        self._fault_draws = faults.make_draws() if faults is not None else None
        self.shedding = shedding
        self._evict = eviction
        self._breaker_cfg = breaker
        self._breaker_overrides: Dict[str, BreakerConfig] = {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._rejected: List[InvocationRecord] = []
        self._reject_lock = threading.Lock()
        self._shed = 0
        self._breaker_rejected = 0
        self._node_lost = 0
        self._redispatches = 0
        self._t0 = time.monotonic()  # loader-fault draw clock for invoke()
        # gray-failure tail tolerance (docs/resilience.md): the sim backend
        # owns its own detector; the runtime backend's lives here, fed by
        # the resilient handles' completion callbacks
        self._hedging_source = None if hedging is None else "constructor"
        self.hedging = resolve_hedging(hedging)
        self._quarantine_source = None if quarantine is None else "constructor"
        self.quarantine = resolve_quarantine(quarantine)
        self._hedging = None        # applied runtime-backend configs
        self._quarantine_cfg = None
        self._slowness = None
        self._quarantine: Optional[QuarantineController] = None
        self._hedges_launched = 0
        self._hedges_won = 0
        self._hedges_wasted = 0
        self._tail_lock = threading.Lock()
        self._fault_pace = 1.0      # replay() pace, for leak/probe timers
        self._leak_stops: Dict[str, threading.Event] = {}
        # loader/admission scheduling ("fifo"|"edf"). None = default "fifo"
        # but adoptable: the first registered spec that declares a scheduler
        # switches the gateway (an explicit constructor choice is not
        # overridable — a conflicting spec raises at register()).
        self._scheduler_source = None if scheduler is None else "constructor"
        self.scheduler = scheduler or "fifo"
        # cluster dispatch ("random"|"locality"|"least_loaded"), same
        # adopt/conflict semantics as the scheduler knob (docs/cluster.md).
        # Stored even for single-node backends so a later spec conflict is
        # still surfaced consistently.
        self._dispatch_source = None if dispatch is None else "constructor"
        self.dispatch = dispatch or "random"
        if self.dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {self.dispatch!r}; "
                f"use one of {DISPATCH_POLICIES}")
        # transfer scheduling ("run_to_completion"|"preemptive"), same
        # adopt/conflict semantics as the scheduler knob (docs/dataplane.md)
        self._transfer_source = None if transfer is None else "constructor"
        self.transfer = transfer or "run_to_completion"
        if self.transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {self.transfer!r}; "
                f"use one of {TRANSFER_MODES}")
        # predictive autoscaling over a dynamic node pool (docs/planner.md);
        # None keeps the pool static. Same adopt/conflict semantics as the
        # other knobs (an AutoscaleConfig is frozen, so equality is exact).
        from repro.core.placement import resolve_autoscale

        self._autoscale_source = None if autoscale is None else "constructor"
        self.autoscale = resolve_autoscale(autoscale)
        # shared GPU compute plane (docs/compute.md): fractional SM slicing
        # + same-function batching. None keeps the seed's exclusive compute
        # FIFO on both backends; same adopt/conflict semantics as the
        # other knobs (a ComputeConfig is frozen, so equality is exact).
        from repro.core.compute import resolve_compute

        self._compute_source = None if compute is None else "constructor"
        self.compute = resolve_compute(compute)
        if backend == "sim":
            from repro.core.simulator import Simulator

            self.sim = Simulator(
                policy, n_nodes=n_nodes, capacity=device_capacity,
                host_capacity=host_capacity,
                exit_ttl=exit_ttl, seed=seed, loader_threads=loader_threads,
                # backend-native deadline defaults: 600 virtual s (sim)
                load_timeout_s=600.0 if load_timeout_s is None else load_timeout_s,
                scheduler=self.scheduler, dispatch=self.dispatch,
                transfer=self.transfer,
                faults=faults, breaker=breaker, shedding=shedding,
                eviction=eviction, autoscale=self.autoscale,
                hedging=self.hedging, quarantine=self.quarantine,
                compute=self.compute,
                **({} if chunk_bytes is None else {"chunk_bytes": chunk_bytes}),
            )
            self._nodes: List = []
        else:
            from repro.core.runtime import ClusterRuntime, SageRuntime

            kw = dict(
                policy=policy, device_capacity=device_capacity,
                host_capacity=host_capacity,
                time_scale=time_scale, exit_ttl=exit_ttl,
                loader_threads=loader_threads,
                load_timeout_s=30.0 if load_timeout_s is None else load_timeout_s,
                max_workers=max_workers, serialize_compute=serialize_compute,
                scheduler=self.scheduler, transfer=self.transfer,
                chunk_bytes=chunk_bytes, compute=self.compute,
            )
            if n_nodes == 1 and self.autoscale is None:
                self.runtime = SageRuntime(**kw)
                self._nodes = [self.runtime]
            else:
                self.runtime = ClusterRuntime(n_nodes=n_nodes, seed=seed,
                                              dispatch=self.dispatch,
                                              eviction=eviction,
                                              autoscale=self.autoscale, **kw)
                self._nodes = list(self.runtime.nodes)
                # dynamic pool: lower every registered spec onto a joiner
                # before dispatch can target it (docs/planner.md)
                self.runtime.on_node_added = self._on_node_added
            self.runtime.sage_init()
            self._fns: Dict[str, List] = {}  # name -> GPUFunction per node
            self._sync_tail_layer()

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    # knobs a spec may declare and a gateway adopts/refuses uniformly
    # ("scheduler": loader/admission ordering; "dispatch": cluster routing;
    # "transfer": run-to-completion vs preemptible chunked streams;
    # "autoscale": predictive node-pool scaling — docs/planner.md;
    # "hedging"/"quarantine": gray-failure tail tolerance —
    # docs/resilience.md)
    # "compute": shared SM slicing + same-function batching —
    # docs/compute.md
    _SPEC_KNOBS = ("scheduler", "dispatch", "transfer", "autoscale",
                   "hedging", "quarantine", "compute")

    def _on_node_added(self, idx: int, node) -> None:
        """ClusterRuntime hook: a node joined the pool (autoscaler or
        explicit ``add_node``). Lower every registered spec onto it —
        each node compiles its own context — before it enters
        ``_nodes``/``_fns`` indexing."""
        for name, spec in self.specs.items():
            fn = spec.to_gpu_function(node.db)
            node.register_function(fn)
            self._fns[name].append(fn)
        self._nodes.append(node)

    def _check_knob(self, spec: FunctionSpec, knob: str) -> None:
        """Raise if the spec's declared ``knob`` value conflicts with a
        pinned gateway (constructor choice or an earlier registered spec)."""
        declared = getattr(spec, knob)
        if (declared is not None and declared != getattr(self, knob)
                and getattr(self, f"_{knob}_source") is not None):
            raise ValueError(
                f"spec {spec.name!r} declares {knob}={declared!r} "
                f"but this gateway runs {getattr(self, knob)!r} "
                f"(set by {getattr(self, f'_{knob}_source')})")

    def _adopt_knob(self, spec: FunctionSpec, knob: str) -> None:
        """A spec may declare the configuration it was validated under. An
        undecided gateway adopts it; conflicts were rejected by
        :meth:`_check_knob` before the backend registration ran. The value
        is applied through the backend's ``set_<knob>`` when it has one (a
        single-node runtime has no dispatch to switch — the knob is still
        recorded so later conflicting specs are refused)."""
        declared = getattr(spec, knob)
        if declared is None:
            return
        if declared == getattr(self, knob):
            if getattr(self, f"_{knob}_source") is None:
                setattr(self, f"_{knob}_source", f"spec {spec.name!r}")
            return
        setattr(self, knob, declared)
        setattr(self, f"_{knob}_source", f"spec {spec.name!r}")
        target = self.sim if self.sim is not None else self.runtime
        setter = getattr(target, f"set_{knob}", None)
        if setter is not None:
            setter(declared)

    def register(self, spec: FunctionSpec) -> None:
        if spec.name in self.specs:
            raise ValueError(f"function {spec.name!r} already registered")
        # knob conflicts must surface before any backend state changes
        for knob in self._SPEC_KNOBS:
            self._check_knob(spec, knob)
        if self.sim is not None:
            self.sim.register(spec.to_sim_function())
        else:
            fns = []
            for node in self._nodes:  # each node compiles its own context
                fn = spec.to_gpu_function(node.db)
                node.register_function(fn)
                fns.append(fn)
            self._fns[spec.name] = fns
            # planner churn signal (docs/planner.md): the cluster's control
            # plane needs the function's working-set bytes to give it a home
            nf = getattr(self.runtime, "note_function", None)
            if nf is not None:
                nf(spec.name, fns[0].total_bytes())
        # adopt/record only once the backend registration succeeded: a spec
        # that failed to lower must not pin the gateway's knobs
        for knob in self._SPEC_KNOBS:
            self._adopt_knob(spec, knob)
        if self.sim is None:
            # a spec-adopted hedging/quarantine knob lands on the gateway's
            # own layer (the sim twin applied it through set_hedging/
            # set_quarantine inside _adopt_knob)
            self._sync_tail_layer()
        if spec.breaker is not None:
            # per-function breaker override beats the gateway-wide config
            if self.sim is not None:
                self.sim.set_function_breaker(spec.name, spec.breaker)
            else:
                self._breaker_overrides[spec.name] = spec.breaker
        self.specs[spec.name] = spec

    # ------------------------------------------------------------------
    # resilience control (runtime backend; the sim gates inside Simulator)
    # ------------------------------------------------------------------
    def _breaker_for(self, name: str) -> Optional[CircuitBreaker]:
        br = self._breakers.get(name)
        if br is None:
            cfg = self._breaker_overrides.get(name, self._breaker_cfg)
            if cfg is None:
                return None
            br = self._breakers[name] = CircuitBreaker(cfg, time.monotonic)
        return br

    def _note_result(self, name: str, ok: bool) -> None:
        br = self._breakers.get(name)
        if br is not None:
            br.record(ok)

    def _shed_pressure(self) -> float:
        """Mean normalized loader pressure over healthy nodes (the same
        :func:`~repro.core.faults.node_pressure` formula the sim uses)."""
        vals = []
        for n in self._nodes:
            if not n.healthy or n.retired:
                continue
            p = n.daemon.pressure()
            vals.append(node_pressure(
                p["pending_admissions"], p["loader_queue"],
                p["loader_threads"], self.shedding.saturation))
        return sum(vals) / len(vals) if vals else 1.0

    def _reject(self, name: str, t: float, deadline_s, priority,
                cls: str, reason: str) -> InvocationRecord:
        """Record a pre-dispatch rejection (shed / breaker-open). The
        record joins ``report()`` so goodput and error_counts() see one
        outcome per request on both drivers."""
        prefix = "ShedError" if cls == "shed" else "BreakerOpenError"
        rec = InvocationRecord(
            request_id=f"gw-{next(self._seq)}-{name}", function=name,
            system=self.policy, arrival_t=t, start_t=t, end_t=t,
            deadline_s=deadline_s, priority=priority,
            error=f"{prefix}: {name}: {reason}", error_class=cls)
        with self._reject_lock:
            self._rejected.append(rec)
            if cls == "shed":
                self._shed += 1
            else:
                self._breaker_rejected += 1
        return rec

    def _gate(self, name: str, t: float, deadline_s, priority):
        """Run the admission gates for one runtime-backend arrival in the
        cross-driver order: loader-fault draw first (the stream advances
        even for rejected requests), then the LoaderJitter draw (its own
        seeded stream — sim ``_arrive`` parity), then shedding, then the
        breaker (last among the gates — ``allow()`` claims a half-open
        probe slot, and a later rejection would leak it). Returns
        ``(injected, jitter_s, rejection)`` where ``rejection`` is a
        record when a gate refused the request."""
        injected = (self._fault_draws.draw(name, t)
                    if self._fault_draws is not None else False)
        jitter_s = (self._fault_draws.jitter(name, t)
                    if self._fault_draws is not None else 0.0)
        if self.shedding is not None:
            p = self._shed_pressure()
            if self.shedding.should_shed(p, priority):
                return injected, jitter_s, self._reject(
                    name, t, deadline_s, priority,
                    "shed", f"shed at pressure {p:.2f}")
        br = self._breaker_for(name)
        if br is not None and not br.allow():
            return injected, jitter_s, self._reject(
                name, t, deadline_s, priority, "breaker", "circuit open")
        return injected, jitter_s, None

    def _resilience_on(self) -> bool:
        """True when runtime invocations need the control-loop handle
        (breaker outcome feed, crash re-dispatch, node-lost counters,
        slowness-detector feed / hedge timers)."""
        return (self._evict or self.faults is not None
                or self._breaker_cfg is not None
                or bool(self._breaker_overrides)
                or self._slowness is not None)

    # -- gray-failure tail tolerance (docs/resilience.md) --------------
    def _sync_tail_layer(self) -> None:
        """(Re)build the runtime backend's slowness layer from the current
        ``hedging``/``quarantine`` knobs (constructor or spec-adopted).
        No-op when nothing changed; the sim backend owns its own copy."""
        if self.sim is not None:
            return
        if (self.hedging == self._hedging
                and self.quarantine == self._quarantine_cfg
                and (self._slowness is not None
                     or (self.hedging is None and self.quarantine is None))):
            return
        self._hedging = self.hedging
        self._quarantine_cfg = self.quarantine
        if self.hedging is None and self.quarantine is None:
            self._slowness = None
            self._quarantine = None
            if hasattr(self.runtime, "health_score"):
                self.runtime.health_score = None
            return
        self._slowness = make_detector(self.hedging, self.quarantine)
        self._quarantine = (
            QuarantineController(self.quarantine, self._slowness)
            if self.quarantine is not None else None)
        if hasattr(self.runtime, "health_score"):
            det, lock = self._slowness, self._tail_lock

            def _score(node_id: str) -> float:
                with lock:
                    return det.health_score(node_id)

            self.runtime.health_score = _score

    def _wl_now(self) -> float:
        """Workload-time clock for the quarantine controller: wall seconds
        since the gateway started, un-scaled by the replay pace, so the
        controller's cooldowns mean the same seconds on both drivers."""
        return (time.monotonic() - self._t0) / self._fault_pace

    def _tail_observe(self, node_id: str, rec: InvocationRecord) -> None:
        """Feed one successful completion to the detector + quarantine
        machine (the runtime image of the sim's ``_tail_complete``)."""
        sl = self._slowness
        if sl is None or rec is None:
            return
        with self._tail_lock:
            sl.observe_record(node_id, rec.function, rec.stages,
                              rec.duration)
        self._quarantine_note(node_id, rec.stages.get("compute", 0.0))

    def _quarantine_note(self, node_id: str, compute_s: float) -> None:
        q = self._quarantine
        if q is None:
            return
        node = next((n for n in self._nodes if n.node_id == node_id), None)
        if node is None or node.draining or node.retired:
            return
        with self._tail_lock:
            action = q.note_completion(node_id, self._wl_now(), compute_s)
        if action in ("quarantine", "retire") \
                and hasattr(self.runtime, "drain_node"):
            self.runtime.drain_node(node_id)
        if action == "quarantine":
            self._schedule_probe()

    def _schedule_probe(self) -> None:
        with self._tail_lock:
            at = self._quarantine.next_probe_at()
        if at is None:
            return
        delay = max(0.0, (at - self._wl_now()) * self._fault_pace)
        tm = threading.Timer(delay, self._probe_fire)
        tm.daemon = True
        tm.start()

    def _probe_fire(self) -> None:
        q = self._quarantine
        if q is None:
            return
        with self._tail_lock:
            due = q.due_probes(self._wl_now())
        for node_id in due:
            self._readmit_node(node_id)
        self._schedule_probe()

    def _readmit_node(self, node_id: str) -> None:
        """Half-open readmission: bring a quarantined node back into the
        dispatch set cold (probation — its next completions are the
        canaries the controller judges)."""
        node = next((n for n in self._nodes if n.node_id == node_id), None)
        if node is None:
            return
        rt = self.runtime
        if node.draining and not node.retired and node.is_idle():
            # finalize the pending drain so readmission starts from the
            # same cold, byte-exact state a finished drain leaves
            node.drain_teardown()
            if getattr(rt, "_control", None) is not None:
                rt._control.node_retired(node.node_id, rt._now())
        if node.daemon.dead:
            node.daemon.restore()
        node.healthy = True
        node.draining = False
        node.retired = False
        if hasattr(rt, "nodes"):
            rt._has_drains = any(n.draining or n.retired for n in rt.nodes)
            if rt._control is not None:
                rt._control.node_provisioned(node.node_id, rt._now())

    # -- MemoryLeak gray failure (runtime image of sim._leak_tick) -----
    def _start_leak(self, node, spec) -> None:
        stop = threading.Event()
        self._leak_stops[node.node_id] = stop
        self._leak_tick(node, spec, stop)

    def _leak_tick(self, node, spec, stop: threading.Event) -> None:
        if stop.is_set() or not node.healthy or node.retired:
            return
        node.daemon.inject_leak(int(spec.rate_bps * _LEAK_TICK_S))
        tm = threading.Timer(_LEAK_TICK_S * self._fault_pace,
                             self._leak_tick, (node, spec, stop))
        tm.daemon = True
        tm.start()

    def _stop_leak(self, node) -> None:
        stop = self._leak_stops.pop(node.node_id, None)
        if stop is not None:
            stop.set()
        node.daemon.reclaim_leak()

    # -- scheduled fault application (replay timers / direct calls) ----
    def _fault_nodes(self, node_name: Optional[str]) -> List:
        nodes = self._nodes
        if node_name is None:
            return list(nodes)
        hit = [n for n in nodes if n.node_id == node_name]
        if not hit:
            raise ValueError(f"fault names unknown node {node_name!r}")
        return hit

    def _apply_fault(self, action: str, spec) -> None:
        """Apply one scheduled fault to the runtime backend (the sim twin
        applies the same plan through ``EventKind.FAULT`` events)."""
        if isinstance(spec, NodeCrash):
            for n in self._fault_nodes(spec.node):
                if action == "crash":
                    n.crash(f"injected crash of {n.node_id}")
                else:
                    n.restore()
        elif isinstance(spec, LinkDegradation):
            for n in self._fault_nodes(spec.node):
                broker = n.paths.db if spec.link == "db" else n.paths.pcie
                if action == "degrade_on":
                    broker.apply_degradation(spec.factor)
                else:
                    broker.clear_degradation(spec.factor)
        elif isinstance(spec, DbFlap):
            for n in self._fault_nodes(spec.node):
                n.daemon.db_down = action == "db_down"
        elif isinstance(spec, SlowNode):
            # gray failure: the node stays up but everything on it runs
            # ``factor`` slower — engine leg via the node's slow_factor
            # (measured-dt stretch in sage_run), transfer legs via both
            # of the node's links (sim _apply_fault parity)
            for n in self._fault_nodes(spec.node):
                if action == "slow_on":
                    n.slow_factor *= spec.factor
                    n.paths.db.apply_degradation(spec.factor)
                    n.paths.pcie.apply_degradation(spec.factor)
                else:
                    n.slow_factor /= spec.factor
                    n.paths.db.clear_degradation(spec.factor)
                    n.paths.pcie.clear_degradation(spec.factor)
        elif isinstance(spec, MemoryLeak):
            for n in self._fault_nodes(spec.node):
                if action == "leak_on":
                    self._start_leak(n, spec)
                else:
                    self._stop_leak(n)

    def resilience_stats(self) -> Dict[str, object]:
        """Control-layer counters, same keys on both backends."""
        if self.sim is not None:
            return self.sim.resilience_stats()
        q = (self._quarantine.stats() if self._quarantine is not None
             else {"quarantines": 0, "readmits": 0})
        return {
            "shed": self._shed,
            "breaker_rejected": self._breaker_rejected,
            "node_lost": self._node_lost,
            "redispatches": self._redispatches,
            "node_crashes": sum(n.crashes for n in self._nodes),
            "node_drains": sum(1 for n in self._nodes
                               if n.draining or n.retired),
            "breaker_states": {name: br.state
                               for name, br in self._breakers.items()},
            "hedges_launched": self._hedges_launched,
            "hedges_won": self._hedges_won,
            "hedges_wasted": self._hedges_wasted,
            "quarantines": q["quarantines"],
            "readmits": q["readmits"],
        }

    def compute_stats(self) -> Dict[str, object]:
        """Shared-compute-plane counters, same keys on both backends
        (docs/compute.md); all-zero "exclusive" when the plane is off."""
        if self.sim is not None:
            return self.sim.compute_stats()
        return self.runtime.compute_stats()

    # ------------------------------------------------------------------
    # placement control plane (docs/planner.md)
    # ------------------------------------------------------------------
    def placement_stats(self) -> Optional[Dict]:
        """Planner/stealer/autoscaler counters + the node-count timeline;
        ``None`` unless the control plane is on (same keys on both
        backends)."""
        if self.sim is not None:
            return self.sim.placement_stats()
        ps = getattr(self.runtime, "placement_stats", None)
        return ps() if ps is not None else None

    def add_node(self):
        """Provision one cold node into the backend's pool (the manual
        form of the autoscaler's scale-up); returns the new node."""
        if self.sim is not None:
            return self.sim.add_node()
        if not hasattr(self.runtime, "add_node"):
            raise RuntimeError(
                "single-node runtime gateway has no node pool; construct "
                "with n_nodes > 1 or autoscale=")
        return self.runtime.add_node()

    def drain_node(self, node) -> None:
        """Gracefully drain one node (name, or index on the runtime
        backend): no new placements; exact teardown once idle."""
        if self.sim is not None:
            self.sim.drain_node(node)
            return
        if not hasattr(self.runtime, "drain_node"):
            raise RuntimeError(
                "single-node runtime gateway has no node pool; construct "
                "with n_nodes > 1 or autoscale=")
        self.runtime.drain_node(node)

    def retire(self, name: str) -> None:
        """Unregister a function (planner churn signal): new invokes
        raise KeyError; resident state ages out via the exit ladders."""
        if name not in self.specs:
            raise KeyError(f"unregistered function {name!r}")
        if self.sim is not None:
            self.sim.retire(name)
        else:
            rf = getattr(self.runtime, "retire_function", None)
            if rf is not None:
                rf(name)
        del self.specs[name]

    # ------------------------------------------------------------------
    # invocation
    # ------------------------------------------------------------------
    def _effective_slo(self, name: str, deadline_s, priority):
        spec = self.specs[name]
        return (spec.deadline_s if deadline_s is None else deadline_s,
                spec.priority if priority is None else priority)

    def _pick_node(self, name: str) -> Tuple[int, Optional[str]]:
        """(node index, residency tier at dispatch) for the runtime
        backend. Multi-node gateways delegate to the cluster's dispatch
        policy (the request must be BUILT for the chosen node — each node
        has its own database and compiled functions — so selection happens
        here, not inside ``ClusterRuntime.submit``)."""
        if len(self._nodes) == 1:
            return 0, None
        return self.runtime.select_node(name)

    def _build_request(self, name: str, node_idx: int, *, seed: int,
                       input_bytes: int, deadline_s, priority,
                       max_retries=None, dispatch_tier=None):
        from repro.core.functions import make_request

        spec = self.specs[name]
        req = make_request(
            self._nodes[node_idx].db, self._fns[name][node_idx],
            batch=spec.batch, seq=spec.seq, input_bytes=input_bytes, seed=seed,
        )
        req.deadline_s, req.priority = self._effective_slo(name, deadline_s, priority)
        req.max_retries = max_retries
        req.dispatch_tier = dispatch_tier
        return req

    def invoke_async(self, name: str, *, seed: int = 0,
                     at: Optional[float] = None,
                     deadline_s: Optional[float] = None,
                     priority: Optional[int] = None,
                     max_retries: Optional[int] = None,
                     input_bytes: int = DEFAULT_INPUT_BYTES) -> Invocation:
        """Submit one invocation; returns an :class:`Invocation` handle.
        ``at`` is a virtual arrival time (sim backend only — the real
        runtime always arrives now). ``max_retries`` is the per-request
        OOM-admission retry budget (None = the flat ``load_timeout_s``)."""
        if name not in self.specs:
            raise KeyError(f"unregistered function {name!r}")
        if self.sim is not None:
            t = self.sim.clock.now() if at is None else at
            dl, pr = self._effective_slo(name, deadline_s, priority)
            rid = f"gw-{next(self._seq)}-{name}"
            self.sim.submit(name, t, deadline_s=dl, priority=pr,
                            request_id=rid, max_retries=max_retries)
            return _SimInvocation(self.sim, rid)
        dl, pr = self._effective_slo(name, deadline_s, priority)
        injected, jitter_s = False, 0.0
        if (self._fault_draws is not None or self.shedding is not None
                or self._breaker_cfg is not None or self._breaker_overrides):
            # ad-hoc invokes draw on wall time since gateway creation;
            # replay() draws on workload time so seeded sequences match
            # the sim's (the draw count per function is what must align)
            injected, jitter_s, rejection = self._gate(
                name, time.monotonic() - self._t0, dl, pr)
            if rejection is not None:
                return _RejectedInvocation(rejection)
        node_idx, tier = self._pick_node(name)
        req = self._build_request(name, node_idx, seed=seed,
                                  input_bytes=input_bytes,
                                  deadline_s=dl, priority=pr,
                                  max_retries=max_retries, dispatch_tier=tier)
        req.fault_injected = injected
        req.jitter_s = jitter_s
        node = self._nodes[node_idx]
        fut = node.submit(req)
        if self._resilience_on():
            return _ResilientInvocation(self, name, node_idx, req, fut,
                                        seed=seed, input_bytes=input_bytes)
        return _RuntimeInvocation(node, fut, req.uuid)

    def invoke(self, name: str, **kw) -> InvocationRecord:
        """Blocking invocation; returns the finished record (the handler's
        return value rides on ``record.result`` for the real backend)."""
        return self.invoke_async(name, **kw).wait()

    # ------------------------------------------------------------------
    # workload replay
    # ------------------------------------------------------------------
    def replay(self, workload: Union[Workload, List[Arrival]], *,
               until: Optional[float] = None, until_pad: float = 300.0,
               pace: float = 1.0, seed: int = 0,
               timeout: Optional[float] = DEFAULT_REPLAY_TIMEOUT_S,
               input_bytes: int = DEFAULT_INPUT_BYTES) -> Telemetry:
        """Drive every arrival of ``workload`` through the backend.

        Simulator: arrivals land at their virtual times and the clock runs
        to ``until`` (default: last arrival + ``until_pad``); ``pace``/
        ``seed``/``input_bytes``/``timeout`` don't apply (no wall clock, no
        real payloads). Real runtime: arrivals are paced open-loop in
        wall-clock time (``pace`` seconds of wall time per workload second)
        and every completion is awaited up to ``timeout`` wall seconds;
        failures stay in ``Telemetry.errors()``. ``until`` cannot cut a
        wall clock short, so passing it on this backend raises rather than
        silently skewing a windowed measurement. Returns ``report()``.
        """
        events = workload.events() if isinstance(workload, Workload) \
            else sorted(workload, key=lambda a: a.t)
        if self.sim is not None:
            for a in events:
                dl, pr = self._effective_slo(a.function, a.deadline_s, a.priority)
                # unique ids: simultaneous arrivals of one function would
                # otherwise collide on the simulator's default "name@t" id
                self.sim.submit(a.function, a.t, deadline_s=dl, priority=pr,
                                request_id=f"gw-{next(self._seq)}-{a.function}")
            horizon = until if until is not None else \
                ((events[-1].t if events else 0.0) + until_pad)
            self.sim.run(until=horizon)
            return self.report()
        if until is not None:
            raise ValueError("replay(until=...) is a virtual-time cutoff; "
                             "the runtime backend always drains — filter "
                             "records by end_t instead")
        handles = []
        # scheduled faults land at t0 + at_s * pace — the wall-clock image
        # of the sim twin's EventKind.FAULT heap entries for the same plan
        timers: List[threading.Timer] = []
        gates_on = (self._fault_draws is not None or self.shedding is not None
                    or self._breaker_cfg is not None or self._breaker_overrides)
        self._fault_pace = pace  # leak/probe timers tick in workload time
        t0 = time.monotonic()
        if self.faults is not None:
            for ft, action, spec in self.faults.events():
                tm = threading.Timer(ft * pace, self._apply_fault,
                                     (action, spec))
                tm.daemon = True
                timers.append(tm)
                tm.start()
        try:
            for i, a in enumerate(events):
                lag = t0 + a.t * pace - time.monotonic()
                if lag > 0:
                    time.sleep(lag)
                dl, pr = self._effective_slo(a.function, a.deadline_s,
                                             a.priority)
                injected, jitter_s = False, 0.0
                if gates_on:
                    # draws use workload time (a.t) so the per-function
                    # draw sequence matches the sim's for the same plan
                    injected, jitter_s, rejection = self._gate(
                        a.function, a.t, dl, pr)
                    if rejection is not None:
                        continue  # recorded; nothing to submit or await
                node_idx, tier = self._pick_node(a.function)
                req = self._build_request(a.function, node_idx, seed=seed + i,
                                          input_bytes=input_bytes,
                                          deadline_s=dl, priority=pr,
                                          dispatch_tier=tier)
                req.fault_injected = injected
                req.jitter_s = jitter_s
                node = self._nodes[node_idx]
                fut = node.submit(req)
                if self._resilience_on():
                    handles.append(_ResilientInvocation(
                        self, a.function, node_idx, req, fut,
                        seed=seed + i, input_bytes=input_bytes))
                else:
                    handles.append(_RuntimeInvocation(node, fut, req.uuid))
            for h in handles:
                h.wait(timeout, strict=False)
            if self._hedging is not None:
                # a hedge winner settles its handle while the cancelled
                # loser is still unwinding on the slow node — drain so
                # every loser's dropped record lands before report()
                self._drain_losers(timeout)
        finally:
            for tm in timers:  # events past the drain are dropped, not leaked
                tm.cancel()
            for stop in self._leak_stops.values():
                stop.set()  # stop ticking past the drain (bytes stay until
                #             a leak_off/crash reclaims them — sim parity)
        return self.report()

    def _drain_losers(self, timeout: Optional[float]) -> None:
        """Block until every node is idle (bounded by ``timeout``).

        Hedge losers cancel cooperatively at engine checkpoints, so a
        loser stuck mid-kernel on a degraded node finishes well after its
        winner; its dropped record only exists once it unwinds.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while any(not n.is_idle() for n in self._nodes):
            if deadline is not None and time.monotonic() >= deadline:
                return
            time.sleep(0.01)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def report(self) -> Telemetry:
        """The unified per-invocation telemetry for this gateway."""
        if self.sim is not None:
            return self.sim.telemetry
        t = self.runtime.telemetry  # ClusterRuntime merges its nodes
        with self._reject_lock:
            rejected = list(self._rejected)
        if rejected:
            if t is self.runtime.telemetry and self._nodes == [self.runtime]:
                # single-node runtime hands out its LIVE telemetry — merge
                # into a copy so rejections never mutate node-local state
                merged = Telemetry()
                for rec in t.snapshot():
                    merged.add(rec)
                t = merged
            for rec in rejected:
                t.add(rec)
        return t

    @property
    def telemetry(self) -> Telemetry:
        return self.report()

    def memory_usage(self) -> Dict[str, int]:
        """Current memory footprint, same keys on both backends (the sim's
        context/host numbers are modeled from live instance state)."""
        if self.sim is not None:
            ctx = 0
            for node in self.sim.nodes:
                for insts in node.instances.values():
                    ctx += sum(i.fn.ctx_bytes for i in insts
                               if i.has_ctx and not i.dead)
            return {"device_used": sum(n.used for n in self.sim.nodes),
                    "context_bytes": ctx,
                    # the node's host-tier admission accounting (resident
                    # shared-RO copies + in-flight private bytes) — the
                    # same definition daemon.host_used reports
                    "host_used": sum(n.host_used for n in self.sim.nodes)}
        usages = [n.memory_usage() for n in self._nodes]
        return {k: sum(u[k] for u in usages) for k in usages[0]}

    def mean_memory_bytes(self) -> float:
        if self.sim is None:
            raise RuntimeError("time-weighted memory traces exist only on "
                               "the sim backend; use memory_usage() instead")
        return self.sim.mean_memory_bytes()

    # ------------------------------------------------------------------
    def shutdown(self) -> None:
        if self.runtime is not None:
            self.runtime.shutdown()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
