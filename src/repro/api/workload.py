"""Workload: backend-agnostic load descriptions.

A workload is a deterministic (seeded) list of timed :class:`Arrival`s with
optional per-request SLO metadata. ``Gateway.replay`` drives the same
object through either backend — virtual time on the simulator, paced
wall-clock time on the real runtime — so one trace can check that both
drivers agree.

Shapes provided here subsume the repo's previous ad-hoc generators:
open-loop Poisson (``poisson_arrivals`` loops), the MAF-like trace
(``core.simulator.maf_like_trace``), bursty load, and multi-function mixes.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union


@dataclass(frozen=True)
class Arrival:
    """One timed request. ``deadline_s``/``priority`` of ``None`` fall back
    to the registered FunctionSpec's defaults at replay time."""

    t: float
    function: str
    deadline_s: Optional[float] = None
    priority: Optional[int] = None


DeadlineLike = Union[None, float, Dict[str, float]]
PriorityLike = Union[None, int, Dict[str, int]]


class Workload:
    """Base class. Subclasses implement ``_generate()``; events are
    generated once, cached, and returned sorted by arrival time.

    ``deadline_s``/``priority`` accept a scalar (every arrival) or a
    ``{function: value}`` dict (mixed-SLO traces — the shape the EDF-vs-FIFO
    scheduling benchmark replays)."""

    duration_s: float = 0.0

    def __init__(self, *, deadline_s: DeadlineLike = None,
                 priority: PriorityLike = None):
        self._deadline_s = deadline_s
        self._priority = priority
        self._cached: Optional[List[Arrival]] = None

    # -- SLO metadata ----------------------------------------------------
    def _deadline_for(self, function: str) -> Optional[float]:
        if isinstance(self._deadline_s, dict):
            return self._deadline_s.get(function)
        return self._deadline_s

    def _priority_for(self, function: str) -> Optional[int]:
        if isinstance(self._priority, dict):
            return self._priority.get(function)
        return self._priority

    def _arrival(self, t: float, function: str) -> Arrival:
        return Arrival(t, function, self._deadline_for(function),
                       self._priority_for(function))

    # -- events ----------------------------------------------------------
    def _generate(self) -> List[Arrival]:
        raise NotImplementedError

    def events(self) -> List[Arrival]:
        if self._cached is None:
            self._cached = sorted(self._generate(), key=lambda a: a.t)
        return self._cached

    def __iter__(self):
        return iter(self.events())

    def __len__(self) -> int:
        return len(self.events())

    def functions(self) -> List[str]:
        return sorted({a.function for a in self.events()})

    def end_t(self) -> float:
        ev = self.events()
        return ev[-1].t if ev else 0.0


def _as_list(functions: Union[str, Sequence[str]]) -> List[str]:
    return [functions] if isinstance(functions, str) else list(functions)


class TraceWorkload(Workload):
    """Explicit events: ``Arrival``s or ``(t, function)`` tuples."""

    def __init__(self, events: Iterable[Union[Arrival, Tuple[float, str]]],
                 **kw):
        super().__init__(**kw)
        self._raw = list(events)
        self.duration_s = max(
            (e.t if isinstance(e, Arrival) else e[0] for e in self._raw),
            default=0.0,
        )

    def _generate(self) -> List[Arrival]:
        return [e if isinstance(e, Arrival) else self._arrival(e[0], e[1])
                for e in self._raw]


class PoissonWorkload(Workload):
    """Open-loop Poisson at ``rate_per_s``; with several functions each
    arrival picks one uniformly. ``max_events`` truncates the stream (for
    count-bounded drivers like examples/serve_workload.py)."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 rate_per_s: float, duration_s: float, *, seed: int = 0,
                 max_events: Optional[int] = None, **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.rate_per_s = float(rate_per_s)
        self.duration_s = float(duration_s)
        self.seed = seed
        self.max_events = max_events

    def _generate(self) -> List[Arrival]:
        rng = random.Random(self.seed)
        out: List[Arrival] = []
        t = 0.0
        while True:
            t += rng.expovariate(self.rate_per_s)
            if t >= self.duration_s:
                break
            fn = self.function_names[rng.randrange(len(self.function_names))]
            out.append(self._arrival(t, fn))
            if self.max_events is not None and len(out) >= self.max_events:
                break
        return out


class MixWorkload(Workload):
    """Multi-function mix: an independent Poisson process per function,
    ``{function: rate_per_s}`` (the contention-benchmark shape)."""

    def __init__(self, rates: Dict[str, float], duration_s: float, *,
                 seed: int = 0, **kw):
        super().__init__(**kw)
        self.rates = dict(rates)
        self.duration_s = float(duration_s)
        self.seed = seed

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for fn in sorted(self.rates):
            rate = self.rates[fn]
            if rate <= 0:
                continue
            # str seeds hash via sha512 (stable across processes), so each
            # function gets its own deterministic stream
            rng = random.Random(f"{self.seed}:{fn}")
            t = 0.0
            while True:
                t += rng.expovariate(rate)
                if t >= self.duration_s:
                    break
                out.append(self._arrival(t, fn))
        return out


class BurstWorkload(Workload):
    """Base-rate Poisson with periodic bursts: every ``period_s`` each
    function runs at ``burst_rate_per_s`` for ``burst_len_s`` (random phase
    per function), modeling flash-crowd traffic."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 base_rate_per_s: float, burst_rate_per_s: float,
                 duration_s: float, *, period_s: float = 600.0,
                 burst_len_s: float = 60.0, seed: int = 0, **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.base_rate = float(base_rate_per_s)
        self.burst_rate = float(burst_rate_per_s)
        self.duration_s = float(duration_s)
        self.period_s = float(period_s)
        self.burst_len_s = float(burst_len_s)
        self.seed = seed

    def _generate(self) -> List[Arrival]:
        # thinning against the max rate: candidates are drawn at the peak
        # rate and kept with probability rate(t)/peak, so the rate is
        # evaluated at the CANDIDATE time — stepping gaps at the previous
        # event's rate would jump clean over burst windows shorter than a
        # base-rate interarrival gap
        out: List[Arrival] = []
        peak = max(self.base_rate, self.burst_rate)
        for fn in self.function_names:
            rng = random.Random(f"{self.seed}:{fn}")
            phase = rng.random() * self.period_s
            t = 0.0
            while True:
                t += rng.expovariate(peak)
                if t >= self.duration_s:
                    break
                in_burst = ((t + phase) % self.period_s) < self.burst_len_s
                rate = self.burst_rate if in_burst else self.base_rate
                if rng.random() < rate / peak:
                    out.append(self._arrival(t, fn))
        return out


class MAFWorkload(Workload):
    """Azure-Functions-like replay (Shahrad et al.): per-function Poisson
    with log-normal rate spread and hour-scale bursts. Wraps the generator
    the trace benchmarks have always used, so replays are bit-identical to
    the pre-gateway ``maf_like_trace`` calls with the same arguments."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 duration_s: float, *, seed: int = 0, mean_rpm: float = 12.0,
                 **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.duration_s = float(duration_s)
        self.seed = seed
        self.mean_rpm = mean_rpm

    def _generate(self) -> List[Arrival]:
        from repro.core.simulator import maf_like_trace

        return [self._arrival(t, f) for t, f in maf_like_trace(
            self.function_names, self.duration_s, seed=self.seed,
            mean_rpm=self.mean_rpm)]
