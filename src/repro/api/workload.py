"""Workload: backend-agnostic load descriptions.

A workload is a deterministic (seeded) list of timed :class:`Arrival`s with
optional per-request SLO metadata. ``Gateway.replay`` drives the same
object through either backend — virtual time on the simulator, paced
wall-clock time on the real runtime — so one trace can check that both
drivers agree.

Shapes provided here subsume the repo's previous ad-hoc generators:
open-loop Poisson (``poisson_arrivals`` loops), the MAF-like trace
(``core.simulator.maf_like_trace``), bursty load, and multi-function mixes.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union


@dataclass(frozen=True)
class Arrival:
    """One timed request. ``deadline_s``/``priority`` of ``None`` fall back
    to the registered FunctionSpec's defaults at replay time."""

    t: float
    function: str
    deadline_s: Optional[float] = None
    priority: Optional[int] = None


DeadlineLike = Union[None, float, Dict[str, float]]
PriorityLike = Union[None, int, Dict[str, int]]


# ---------------------------------------------------------------------------
# canonical trace generators (moved here from ``repro.core.simulator``,
# which keeps thin deprecated aliases)
# ---------------------------------------------------------------------------
def poisson_arrivals(rate_per_s: float, duration_s: float,
                     rng: random.Random) -> List[float]:
    """Open-loop Poisson arrival times in ``[0, duration_s)``."""
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate_per_s)
        if t >= duration_s:
            return out
        out.append(t)


def maf_like_trace(
    functions: List[str], duration_s: float, seed: int = 0,
    mean_rpm: float = 12.0,
) -> List[Tuple[float, str]]:
    """Azure-Functions-like trace: per-function Poisson with log-normal rate
    spread and hour-scale bursts (Shahrad et al.: most functions see a few
    to dozens of requests/minute)."""
    rng = random.Random(seed)
    events: List[Tuple[float, str]] = []
    for f in functions:
        rate = (mean_rpm / 60.0) * math.exp(rng.gauss(0.0, 0.8))
        burst_phase = rng.random() * duration_s
        t = 0.0
        while True:
            # burst modulation: 2x rate inside a 10% duty window
            mult = 2.0 if ((t + burst_phase) % 600.0) < 60.0 else 1.0
            t += rng.expovariate(rate * mult)
            if t >= duration_s:
                break
            events.append((t, f))
    events.sort()
    return events


class Workload:
    """Base class. Subclasses implement ``_generate()``; events are
    generated once, cached, and returned sorted by arrival time.

    ``deadline_s``/``priority`` accept a scalar (every arrival) or a
    ``{function: value}`` dict (mixed-SLO traces — the shape the EDF-vs-FIFO
    scheduling benchmark replays)."""

    duration_s: float = 0.0

    def __init__(self, *, deadline_s: DeadlineLike = None,
                 priority: PriorityLike = None):
        self._deadline_s = deadline_s
        self._priority = priority
        self._cached: Optional[List[Arrival]] = None

    # -- SLO metadata ----------------------------------------------------
    def _deadline_for(self, function: str) -> Optional[float]:
        if isinstance(self._deadline_s, dict):
            return self._deadline_s.get(function)
        return self._deadline_s

    def _priority_for(self, function: str) -> Optional[int]:
        if isinstance(self._priority, dict):
            return self._priority.get(function)
        return self._priority

    def _arrival(self, t: float, function: str) -> Arrival:
        return Arrival(t, function, self._deadline_for(function),
                       self._priority_for(function))

    # -- events ----------------------------------------------------------
    def _generate(self) -> List[Arrival]:
        raise NotImplementedError

    def events(self) -> List[Arrival]:
        if self._cached is None:
            self._cached = sorted(self._generate(), key=lambda a: a.t)
        return self._cached

    def stream(self) -> Iterator[Arrival]:
        """Arrivals in time order, lazily where the shape allows it.

        The base implementation falls back to the materialized ``events()``
        list; per-function workloads override ``_function_streams`` and get
        a true lazy merge (``heapq.merge`` over per-function generators —
        the million-invocation replay path, which never holds the whole
        trace in memory). The merge is stable, so the ordering of
        simultaneous arrivals matches ``events()``' stable sort."""
        streams = self._function_streams()
        if streams is None:
            return iter(self.events())
        return heapq.merge(*streams, key=lambda a: a.t)

    def _function_streams(self) -> Optional[List[Iterator[Arrival]]]:
        """Per-function lazy arrival generators (each already time-sorted),
        or ``None`` when the shape only exists materialized."""
        return None

    def __iter__(self):
        return iter(self.events())

    def __len__(self) -> int:
        return len(self.events())

    def functions(self) -> List[str]:
        return sorted({a.function for a in self.events()})

    def end_t(self) -> float:
        ev = self.events()
        return ev[-1].t if ev else 0.0


def _as_list(functions: Union[str, Sequence[str]]) -> List[str]:
    return [functions] if isinstance(functions, str) else list(functions)


class TraceWorkload(Workload):
    """Explicit events: ``Arrival``s or ``(t, function)`` tuples."""

    def __init__(self, events: Iterable[Union[Arrival, Tuple[float, str]]],
                 **kw):
        super().__init__(**kw)
        self._raw = list(events)
        self.duration_s = max(
            (e.t if isinstance(e, Arrival) else e[0] for e in self._raw),
            default=0.0,
        )

    def _generate(self) -> List[Arrival]:
        return [e if isinstance(e, Arrival) else self._arrival(e[0], e[1])
                for e in self._raw]


class PoissonWorkload(Workload):
    """Open-loop Poisson at ``rate_per_s``; with several functions each
    arrival picks one uniformly. ``max_events`` truncates the stream (for
    count-bounded drivers like examples/serve_workload.py)."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 rate_per_s: float, duration_s: float, *, seed: int = 0,
                 max_events: Optional[int] = None, **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.rate_per_s = float(rate_per_s)
        self.duration_s = float(duration_s)
        self.seed = seed
        self.max_events = max_events

    def _generate(self) -> List[Arrival]:
        return list(self._lazy())

    def _lazy(self) -> Iterator[Arrival]:
        rng = random.Random(self.seed)
        n = 0
        t = 0.0
        while True:
            t += rng.expovariate(self.rate_per_s)
            if t >= self.duration_s:
                return
            fn = self.function_names[rng.randrange(len(self.function_names))]
            yield self._arrival(t, fn)
            n += 1
            if self.max_events is not None and n >= self.max_events:
                return

    def _function_streams(self) -> Optional[List[Iterator[Arrival]]]:
        # one shared rng drives rate and function choice, so the lazy form
        # is a single already-sorted stream
        return [self._lazy()]


class MixWorkload(Workload):
    """Multi-function mix: an independent Poisson process per function,
    ``{function: rate_per_s}`` (the contention-benchmark shape)."""

    def __init__(self, rates: Dict[str, float], duration_s: float, *,
                 seed: int = 0, **kw):
        super().__init__(**kw)
        self.rates = dict(rates)
        self.duration_s = float(duration_s)
        self.seed = seed

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for s in self._function_streams():
            out.extend(s)
        return out

    def _one(self, fn: str, rate: float) -> Iterator[Arrival]:
        # str seeds hash via sha512 (stable across processes), so each
        # function gets its own deterministic stream
        rng = random.Random(f"{self.seed}:{fn}")
        t = 0.0
        while True:
            t += rng.expovariate(rate)
            if t >= self.duration_s:
                return
            yield self._arrival(t, fn)

    def _function_streams(self) -> List[Iterator[Arrival]]:
        return [self._one(fn, self.rates[fn])
                for fn in sorted(self.rates) if self.rates[fn] > 0]


class BurstWorkload(Workload):
    """Base-rate Poisson with periodic bursts: every ``period_s`` each
    function runs at ``burst_rate_per_s`` for ``burst_len_s`` (random phase
    per function), modeling flash-crowd traffic."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 base_rate_per_s: float, burst_rate_per_s: float,
                 duration_s: float, *, period_s: float = 600.0,
                 burst_len_s: float = 60.0, seed: int = 0, **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.base_rate = float(base_rate_per_s)
        self.burst_rate = float(burst_rate_per_s)
        self.duration_s = float(duration_s)
        self.period_s = float(period_s)
        self.burst_len_s = float(burst_len_s)
        self.seed = seed

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for s in self._function_streams():
            out.extend(s)
        return out

    def _one(self, fn: str) -> Iterator[Arrival]:
        # thinning against the max rate: candidates are drawn at the peak
        # rate and kept with probability rate(t)/peak, so the rate is
        # evaluated at the CANDIDATE time — stepping gaps at the previous
        # event's rate would jump clean over burst windows shorter than a
        # base-rate interarrival gap
        peak = max(self.base_rate, self.burst_rate)
        rng = random.Random(f"{self.seed}:{fn}")
        phase = rng.random() * self.period_s
        t = 0.0
        while True:
            t += rng.expovariate(peak)
            if t >= self.duration_s:
                return
            in_burst = ((t + phase) % self.period_s) < self.burst_len_s
            rate = self.burst_rate if in_burst else self.base_rate
            if rng.random() < rate / peak:
                yield self._arrival(t, fn)

    def _function_streams(self) -> List[Iterator[Arrival]]:
        return [self._one(fn) for fn in self.function_names]


class DiurnalWorkload(Workload):
    """Day-scale sinusoidal load: per-function Poisson whose rate swings
    ``base_rate_per_s * (1 ± amplitude)`` over ``period_s`` (default 24 h,
    compressed periods make quick experiments). Generated by thinning
    against the peak rate, like :class:`BurstWorkload`, so short periods
    are never stepped over."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 base_rate_per_s: float, duration_s: float, *,
                 amplitude: float = 0.8, period_s: float = 86400.0,
                 phase_s: float = 0.0, seed: int = 0, **kw):
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.base_rate = float(base_rate_per_s)
        self.duration_s = float(duration_s)
        self.amplitude = float(amplitude)
        self.period_s = float(period_s)
        self.phase_s = float(phase_s)
        self.seed = seed

    def rate_at(self, t: float) -> float:
        return self.base_rate * (1.0 + self.amplitude * math.sin(
            2.0 * math.pi * (t + self.phase_s) / self.period_s))

    def _one(self, fn: str) -> Iterator[Arrival]:
        peak = self.base_rate * (1.0 + self.amplitude)
        rng = random.Random(f"{self.seed}:{fn}")
        t = 0.0
        while True:
            t += rng.expovariate(peak)
            if t >= self.duration_s:
                return
            if rng.random() < self.rate_at(t) / peak:
                yield self._arrival(t, fn)

    def _function_streams(self) -> List[Iterator[Arrival]]:
        return [self._one(fn) for fn in self.function_names]

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for s in self._function_streams():
            out.extend(s)
        return out


class FlashCrowdWorkload(Workload):
    """Baseline Poisson with sudden crowd spikes: at each time in
    ``spike_times_s`` the rate jumps to ``spike_factor * base`` and decays
    back exponentially with time constant ``decay_s`` — the
    cold-start-stampede shape GPU serverless platforms fear most (every
    spike lands on functions whose instances have exited)."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 base_rate_per_s: float, duration_s: float, *,
                 spike_times_s: Sequence[float] = (),
                 spike_factor: float = 10.0, decay_s: float = 30.0,
                 seed: int = 0, **kw):
        if spike_factor < 1.0:
            raise ValueError(f"spike_factor must be >= 1, got {spike_factor}")
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.base_rate = float(base_rate_per_s)
        self.duration_s = float(duration_s)
        self.spike_times_s = sorted(float(t) for t in spike_times_s)
        self.spike_factor = float(spike_factor)
        self.decay_s = float(decay_s)
        self.seed = seed

    def rate_at(self, t: float) -> float:
        boost = 0.0
        for ts in self.spike_times_s:
            if ts > t:
                break  # spikes are sorted; later ones have not hit yet
            boost += (self.spike_factor - 1.0) * math.exp(
                -(t - ts) / self.decay_s)
        return self.base_rate * (1.0 + boost)

    def _one(self, fn: str) -> Iterator[Arrival]:
        peak = self.base_rate * (
            1.0 + (self.spike_factor - 1.0) * max(1, len(self.spike_times_s))
            if self.spike_times_s else 1.0)
        rng = random.Random(f"{self.seed}:{fn}")
        t = 0.0
        while True:
            t += rng.expovariate(peak)
            if t >= self.duration_s:
                return
            if rng.random() < self.rate_at(t) / peak:
                yield self._arrival(t, fn)

    def _function_streams(self) -> List[Iterator[Arrival]]:
        return [self._one(fn) for fn in self.function_names]

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for s in self._function_streams():
            out.extend(s)
        return out


class MultiRegionWorkload(Workload):
    """Composition of per-region workloads, each shifted by a per-region
    time offset (timezone skew): ``{"us": wl_a, "eu": wl_b}`` with
    ``offsets_s={"eu": 3600.0}`` replays ``wl_b`` an hour later. The
    shifted union models follow-the-sun load on one shared cluster —
    regions peak at different times, so sharing-aware dispatch can pack
    them (docs/cluster.md)."""

    def __init__(self, regions: Dict[str, Workload], *,
                 offsets_s: Optional[Dict[str, float]] = None, **kw):
        super().__init__(**kw)
        if not regions:
            raise ValueError("regions must not be empty")
        self.regions = dict(regions)
        self.offsets_s = dict(offsets_s or {})
        unknown = set(self.offsets_s) - set(self.regions)
        if unknown:
            raise ValueError(f"offsets for unknown regions: {sorted(unknown)}")
        self.duration_s = max(
            wl.duration_s + self.offsets_s.get(name, 0.0)
            for name, wl in self.regions.items())

    def _shift(self, name: str) -> Iterator[Arrival]:
        dt = self.offsets_s.get(name, 0.0)
        for a in self.regions[name].stream():
            yield Arrival(a.t + dt, a.function, a.deadline_s, a.priority)

    def _function_streams(self) -> List[Iterator[Arrival]]:
        return [self._shift(name) for name in sorted(self.regions)]

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for s in self._function_streams():
            out.extend(s)
        return out


class MAFWorkload(Workload):
    """Azure-Functions-like replay (Shahrad et al.): per-function Poisson
    with log-normal rate spread and hour-scale bursts. Wraps the generator
    the trace benchmarks have always used, so replays are bit-identical to
    the pre-gateway ``maf_like_trace`` calls with the same arguments."""

    def __init__(self, functions: Union[str, Sequence[str]],
                 duration_s: float, *, seed: int = 0, mean_rpm: float = 12.0,
                 **kw):
        super().__init__(**kw)
        self.function_names = _as_list(functions)
        self.duration_s = float(duration_s)
        self.seed = seed
        self.mean_rpm = mean_rpm

    def _generate(self) -> List[Arrival]:
        return [self._arrival(t, f) for t, f in maf_like_trace(
            self.function_names, self.duration_s, seed=self.seed,
            mean_rpm=self.mean_rpm)]


class ChaosWorkload(Workload):
    """Mixed-priority Poisson mix for the resilience benchmarks
    (benchmarks/chaos.py, docs/resilience.md): each function carries a
    (rate, deadline, priority) triple, so one trace holds both the tight
    high-priority class the shedder protects and the loose low-priority
    class it sacrifices first. Arrival streams are per-function seeded,
    identical on both drivers."""

    def __init__(self, classes: Dict[str, Tuple[float, float, int]],
                 duration_s: float, *, seed: int = 0):
        # classes: {function: (rate_per_s, deadline_s, priority)}
        super().__init__(
            deadline_s={f: c[1] for f, c in classes.items()},
            priority={f: c[2] for f, c in classes.items()})
        self.classes = dict(classes)
        self.duration_s = float(duration_s)
        self.seed = seed

    def _generate(self) -> List[Arrival]:
        out: List[Arrival] = []
        for fn in sorted(self.classes):
            rate = self.classes[fn][0]
            if rate <= 0:
                continue
            rng = random.Random(f"{self.seed}:{fn}")
            t = 0.0
            while True:
                t += rng.expovariate(rate)
                if t >= self.duration_s:
                    break
                out.append(self._arrival(t, fn))
        return out
