"""Serving step factories (prefill / decode) — thin jit-able wrappers used by
the SAGE runtime, the launcher, and the dry-run."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        logits, cache, _ = prefill(cfg, params, batch, cache)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, tokens: jax.Array, positions: jax.Array, cache):
        return decode_step(cfg, params, tokens, positions, cache)

    return serve_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Abstract (ShapeDtypeStruct) cache pytree without allocating."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, enc_len))
