from repro.serving.engine import (  # noqa: F401
    cache_shapes,
    greedy_sample,
    make_decode_step,
    make_prefill_step,
)
