"""Roofline terms from a dry-run analysis record.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment constants).

All analyzer quantities are *per device* (the SPMD module is the per-device
program), so:

  compute_s    = flops / peak_flops
  memory_s     = hbm_bytes / hbm_bw
  collective_s = collective_bytes / link_bw     (operand-size sum, spec defn)

MODEL_FLOPS uses the 6*N*D / 2*N*D convention (train / inference) with
N = active params (MoE-aware), D = tokens per step — the ratio against
compiled dot-FLOPs exposes remat recompute, causal waste, and dispatch
overhead (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
LINK_BW = 50e9       # bytes/s / link (ICI)


def model_flops(cfg: ModelConfig, mode: str, tokens: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (global)."""
    n = cfg.active_param_count()
    mult = 6.0 if mode == "train" else 2.0
    return mult * n * tokens


def roofline_from_report(
    cfg: ModelConfig, report: Dict, *, chips: int, mode: str, tokens: int
) -> Dict:
    flops = report["flops"]
    dot_flops = report["dot_flops"]
    hbm = report["hbm_bytes"]
    coll = report["collective_bytes"]
    coll_traffic = report["collective_traffic_bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    # TPU-fusion-aware memory estimate (elementwise fused away); falls back
    # to the conservative bound for artifacts predating the field
    memory_fused_s = report.get("hbm_bytes_fused", hbm) / HBM_BW
    mf = model_flops(cfg, mode, tokens)
    hlo_global_flops = flops * chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_fused_s": memory_fused_s,
        "collective_s": collective_s,
        "collective_traffic_s": coll_traffic / LINK_BW,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global_flops,
        "useful_flops_ratio": mf / hlo_global_flops if hlo_global_flops else 0.0,
        "step_time_bound_s": max(terms.values()),
        # fraction of the compute roofline actually achieved if the dominant
        # term were the wall clock (MODEL_FLOPS / (chips*peak) / bound)
        "roofline_fraction": (
            (mf / (chips * PEAK_FLOPS)) / max(max(terms.values()), 1e-30)
        ),
    }
