"""Scan-aware HLO cost analysis for the roofline report.

``compiled.cost_analysis()`` visits each ``while`` body **once**, so for a
scan-over-layers model it under-counts FLOPs/bytes by ~num_layers x (verified
empirically — see EXPERIMENTS.md §Roofline methodology). This module parses
``compiled.as_text()`` (the post-SPMD, per-device HLO), builds the call graph
(entry -> while bodies -> fusions), multiplies every computation's cost by its
execution count (``backend_config={"known_trip_count":...}``), and reports:

* ``flops``           — dot FLOPs (2 * prod(out) * prod(contracting)) plus
                        elementwise/reduce FLOPs, per device;
* ``hbm_bytes``       — operand+result bytes of every *scheduled* op
                        (fusion-internal ops excluded: they live in
                        VMEM/registers on TPU), per device;
* ``collective_bytes``— sum of operand sizes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute
                        (spec definition), plus a per-device *traffic*
                        estimate using ring factors, per device;
* per-collective breakdown for the §Perf iteration log.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

# ops whose output elements each cost ~1 flop
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "tanh", "log",
    "log-plus-one", "rsqrt", "sqrt", "power", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "logistic", "cbrt",
    "atan2", "erf", "remainder", "select", "clamp",
}

_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# ops that on TPU would fuse into neighbours (no HBM round-trip of their
# own); excluded from the *fused* bytes estimate. The conservative
# ``hbm_bytes`` keeps them (CPU-fusion boundaries = upper bound).
_FUSABLE = _ELEMENTWISE | {
    "broadcast", "compare", "convert", "reshape", "slice", "and", "or",
    "not", "xor", "sign", "is-finite", "reduce-precision", "map",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes_elems(shape_txt: str) -> Tuple[int, int]:
    """Total (bytes, elements) of a shape string (tuple-aware)."""
    total_b, total_e = 0, 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES and dt not in ("token",):
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dt, 4)
    return total_b, total_e


def _shape_dims(shape_txt: str) -> List[int]:
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False
    raw_operands: str = ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def _parse_instr(line: str) -> Optional[Instr]:
    is_root = line.lstrip().startswith("ROOT ")
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    rhs = rhs.strip()
    # shape: tuple or single
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, rest = rhs[: i + 1], rhs[i + 1 :].strip()
                    break
        else:
            return None
    else:
        sm = re.match(r"([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?(?:\s*)?)", rhs)
        if not sm:
            return None
        shape, rest = sm.group(1), rhs[sm.end() :].strip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    # operand section: names only, no nested parens
    end = rest.find(")", om.end())
    if end < 0:
        return None
    operand_txt = rest[om.end() : end]
    operands = re.findall(r"%([\w.\-]+)", operand_txt)
    attrs = rest[end + 1 :]
    return Instr(name=name, shape=shape, opcode=opcode, operands=operands,
                 attrs=attrs, is_root=is_root, raw_operands=operand_txt)


def parse_hlo(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "(" in line:
                cur = Computation(name=m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(s)
        if ins:
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    return comps, entry


@dataclass
class CostReport:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0                 # upper bound (CPU-fusion boundaries)
    hbm_bytes_fused: float = 0.0           # TPU estimate (elementwise fused away)
    collective_bytes: float = 0.0          # spec: sum of operand sizes
    collective_traffic_bytes: float = 0.0  # ring-factor per-device estimate
    collectives: Dict[str, float] = field(default_factory=dict)   # opcode -> operand bytes
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_details: List[Tuple[str, str, float, int]] = field(default_factory=list)
    bytes_by_opcode: Dict[str, float] = field(default_factory=dict)
    while_trips: List[int] = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_fused": self.hbm_bytes_fused,
            "collective_bytes": self.collective_bytes,
            "collective_traffic_bytes": self.collective_traffic_bytes,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "bytes_by_opcode": self.bytes_by_opcode,
            "while_trips": self.while_trips,
        }


def _group_size(attrs: str, num_partitions: int) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return num_partitions


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_b, out_e = _shape_bytes_elems(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if m and ins.operands:
        lhs = comp.by_name.get(ins.operands[0])
        if lhs is not None:
            dims = _shape_dims(lhs.shape)
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_e * contract


def _shape_of(comp: Computation, name: str) -> str:
    ins = comp.by_name.get(name)
    return ins.shape if ins is not None else ""


def _fusion_traffic(comps: Dict[str, Computation], comp: Computation,
                    ins: Instr) -> float:
    """HBM traffic of one fusion op, seeing through dynamic-(update-)slice:

    * an operand consumed ONLY by dynamic-slice ops costs the slice bytes,
      not the full buffer (a scan body reads one layer of the stacked
      params/cache per iteration);
    * an operand consumed ONLY as the in-place target of dynamic-update-
      slice costs the update bytes (one token written into a 32k cache);
    * a root that is a dynamic-update-slice (or a tuple of them) writes the
      update bytes, not the whole aliased buffer.
    """
    body = None
    for m in re.finditer(r"calls=%?([\w.\-]+)", ins.attrs):
        body = comps.get(m.group(1))
    if body is None:
        ob, _ = _shape_bytes_elems(ins.shape)
        opnd = sum(_shape_bytes_elems(_shape_of(comp, o))[0] for o in ins.operands)
        return ob + opnd, ob + opnd

    # map parameter index -> body instruction
    params: Dict[int, Instr] = {}
    for bi in body.instrs:
        if bi.opcode == "parameter":
            pm = re.match(r"\s*(\d+)", bi.raw_operands)
            idx = int(pm.group(1)) if pm else len(params)
            params[idx] = bi
    # fall back: parameters in order of appearance
    if not params:
        order = [bi for bi in body.instrs if bi.opcode == "parameter"]
        params = dict(enumerate(order))

    _CAST_OPS = {"convert", "bitcast", "copy", "reshape", "broadcast",
                 "transpose"}

    def _trace(name: str) -> Optional[Instr]:
        """Follow unary cast/layout ops back to the producing op."""
        seen = 0
        e = body.by_name.get(name)
        while e is not None and e.opcode in _CAST_OPS and e.operands and seen < 8:
            e = body.by_name.get(e.operands[0])
            seen += 1
        return e

    def dus_update_bytes(dus: Instr) -> float:
        if len(dus.operands) >= 2:
            return _shape_bytes_elems(_shape_of(body, dus.operands[1]))[0] or 0.0
        return 0.0

    total = 0.0
    root = next((bi for bi in body.instrs if bi.is_root), None)
    root_real = _trace(root.name) if root is not None else None
    if root_real is not None and root_real.opcode == "dynamic-update-slice":
        total += 2 * dus_update_bytes(root_real)
    elif root_real is not None and root_real.opcode == "scatter":
        upd = (_shape_bytes_elems(_shape_of(body, root_real.operands[2]))[0]
               if len(root_real.operands) > 2 else 0.0)
        total += 3 * upd
    elif root is not None and root.opcode == "tuple":
        for o in root.operands:
            e = _trace(o)
            if e is not None and e.opcode == "dynamic-update-slice":
                total += 2 * dus_update_bytes(e)
            else:
                total += _shape_bytes_elems(_shape_of(body, o))[0]
    else:
        total += _shape_bytes_elems(ins.shape)[0]

    # --- operand side
    for idx, oname in enumerate(ins.operands):
        pin = params.get(idx)
        full = _shape_bytes_elems(_shape_of(comp, oname))[0]
        if pin is None:
            total += full
            continue
        consumers = [bi for bi in body.instrs if pin.name in bi.operands]
        if consumers and all(c.opcode == "dynamic-slice" for c in consumers):
            total += sum(_shape_bytes_elems(c.shape)[0] for c in consumers)
        elif consumers and all(
            c.opcode in ("dynamic-update-slice", "scatter") and c.operands
            and c.operands[0] == pin.name for c in consumers
        ):
            total += 0.0  # in-place update target: write counted on out side
        else:
            total += full

    # TPU-estimate side: a fusion whose every non-parameter op is a pure
    # cast/layout op would not exist in a native-bf16 TPU program (the CPU
    # backend upcasts bf16 dots to f32, round-tripping whole caches)
    pure_cast = all(
        bi.opcode in _CAST_OPS or bi.opcode in ("parameter", "constant", "tuple")
        for bi in body.instrs
    )
    fused_total = 0.0 if pure_cast else total
    return total, fused_total


def analyze_hlo_text(txt: str, num_partitions: Optional[int] = None) -> CostReport:
    if num_partitions is None:
        m = re.search(r"num_partitions=(\d+)", txt)
        num_partitions = int(m.group(1)) if m else 1
    comps, entry = parse_hlo(txt)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs)) if comps else None
    rep = CostReport()
    if entry is None:
        return rep

    def attr_comp(attrs: str, key: str) -> List[str]:
        out = []
        for m in re.finditer(key + r"=%?([\w.\-]+)", attrs):
            out.append(m.group(1))
        return out

    def walk(comp_name: str, mult: float, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            out_b, out_e = _shape_bytes_elems(ins.shape)
            opnd_b = 0
            for o in ins.operands:
                src = comp.by_name.get(o)
                if src is not None:
                    b, _ = _shape_bytes_elems(src.shape)
                    opnd_b += b
            # ---- flops
            if op == "dot":
                f = _dot_flops(ins, comp) * mult
                rep.flops += f
                rep.dot_flops += f
            elif op in _ELEMENTWISE:
                rep.flops += out_e * mult
            elif op in ("reduce", "reduce-window"):
                _, in_e = (0, 0)
                if ins.operands:
                    src = comp.by_name.get(ins.operands[0])
                    if src is not None:
                        _, in_e = _shape_bytes_elems(src.shape)
                rep.flops += in_e * mult
            # ---- bytes
            if count_bytes and op not in _NO_BYTES and op != "while":
                traffic_fused = None
                if op == "fusion":
                    traffic, traffic_fused = _fusion_traffic(comps, comp, ins)
                elif op == "dynamic-slice":
                    traffic = 2.0 * out_b  # read slice + write slice
                elif op == "dynamic-update-slice":
                    upd = (_shape_bytes_elems(_shape_of(comp, ins.operands[1]))[0]
                           if len(ins.operands) > 1 else out_b)
                    traffic = 2.0 * upd  # in-place read-modify-write of slice
                elif op == "scatter":
                    upd = (_shape_bytes_elems(_shape_of(comp, ins.operands[2]))[0]
                           if len(ins.operands) > 2 else out_b)
                    traffic = 3.0 * upd  # read idx+update, RMW the slots
                else:
                    traffic = out_b + opnd_b
                rep.hbm_bytes += traffic * mult
                rep.bytes_by_opcode[op] = rep.bytes_by_opcode.get(op, 0.0) + \
                    traffic * mult
                if op not in _FUSABLE:
                    rep.hbm_bytes_fused += (
                        traffic_fused if traffic_fused is not None else traffic
                    ) * mult
            # ---- collectives
            if op in _COLLECTIVES:
                base = op.replace("-start", "")
                gs = _group_size(ins.attrs, num_partitions)
                rep.collective_bytes += opnd_b * mult
                rep.collectives[base] = rep.collectives.get(base, 0.0) + opnd_b * mult
                rep.collective_counts[base] = rep.collective_counts.get(base, 0) + int(mult)
                if base == "all-gather":
                    traffic = out_b * (gs - 1) / gs
                elif base == "all-reduce":
                    traffic = 2.0 * opnd_b * (gs - 1) / gs
                elif base == "reduce-scatter":
                    traffic = opnd_b * (gs - 1) / gs
                elif base == "all-to-all":
                    traffic = opnd_b * (gs - 1) / gs
                else:  # collective-permute
                    traffic = opnd_b
                rep.collective_traffic_bytes += traffic * mult
                rep.collective_details.append((base, ins.shape, opnd_b * mult, gs))
            # ---- recursion
            if op == "while":
                trips = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trips = int(tm.group(1))
                rep.while_trips.append(trips)
                for b in attr_comp(ins.attrs, "body"):
                    walk(b, mult * trips, True)
                for c in attr_comp(ins.attrs, "condition"):
                    walk(c, mult * trips, False)
            elif op == "fusion":
                for c in attr_comp(ins.attrs, "calls"):
                    walk(c, mult, False)  # fusion-internal = VMEM, no HBM bytes
            elif op == "call":
                for c in attr_comp(ins.attrs, "to_apply"):
                    walk(c, mult, count_bytes)
            elif op == "conditional":
                for c in attr_comp(ins.attrs, "branch_computations"):
                    walk(c, mult, count_bytes)

    walk(entry, 1.0, True)
    return rep


def xla_cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: older JAX returns a
    one-dict-per-device list, newer returns the dict directly — callers
    always get a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def analyze_compiled(compiled) -> dict:
    """Full report for a compiled executable: parsed costs + memory stats."""
    txt = compiled.as_text()
    rep = analyze_hlo_text(txt)
    out = rep.as_dict()
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        ca = xla_cost_analysis(compiled)
        out["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        }
    except Exception as e:  # pragma: no cover
        out["xla_cost_analysis"] = {"error": str(e)}
    return out
