"""Activation sharding constraints, injected without polluting model code.

Step factories set a policy (name -> PartitionSpec) for the duration of
tracing; the model calls ``constrain(x, 'residual')`` at scan-carry
boundaries. With no policy active this is the identity, so model code runs
unchanged on a single device.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_tls = threading.local()


def current_policy() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_tls, "policy", None)


@contextmanager
def activation_policy(policy: Optional[Dict[str, PartitionSpec]]):
    prev = current_policy()
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def constrain(x: jax.Array, name: str) -> jax.Array:
    policy = current_policy()
    if policy is None or name not in policy:
        return x
    return jax.lax.with_sharding_constraint(x, policy[name])
