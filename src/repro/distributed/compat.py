"""JAX-version compatibility shims for mesh construction.

The ``AbstractMesh`` constructor changed across JAX releases:

* 0.4.37 takes a single ``shape_tuple`` of ``(name, size)`` pairs,
* 0.5+ takes ``(axis_sizes, axis_names)`` positionally,

and ``jax.sharding.AxisType`` (the ``axis_types=`` kwarg on
``jax.make_mesh``) only exists from 0.6. Every mesh construction in this
repo goes through these two helpers so the sharding rules and launch code
work on any installed JAX.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import AbstractMesh


def abstract_mesh(sizes: Sequence[int], names: Sequence[str]) -> AbstractMesh:
    """Device-free mesh of the given axis sizes/names on any JAX version."""
    sizes_t: Tuple[int, ...] = tuple(int(s) for s in sizes)
    names_t: Tuple[str, ...] = tuple(names)
    if len(sizes_t) != len(names_t):
        raise ValueError(f"mesh rank mismatch: {sizes_t} vs {names_t}")
    try:  # new-style: positional (sizes, names)
        return AbstractMesh(sizes_t, names_t)
    except TypeError:
        pass
    # 0.4.37-style: one tuple of (name, size) pairs
    return AbstractMesh(tuple(zip(names_t, sizes_t)))


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (0.6+, ``check_vma``) or the 0.4.x
    ``jax.experimental.shard_map`` (``check_rep``), replication checks off."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with ``axis_types=Auto`` where supported."""
    shape_t = tuple(int(s) for s in shape)
    axes_t = tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape_t, axes_t, axis_types=(axis_type.Auto,) * len(axes_t)
            )
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape_t, axes_t)
