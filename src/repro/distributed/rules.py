"""Sharding rules: pytree path patterns -> PartitionSpec.

One rules table per execution mode:

* ``train`` / ``prefill``: FSDP over the data-ish axes (``pod`` + ``data``)
  stacked on TP over ``model``. Every weight and optimizer tensor is sharded
  on *both* axes; XLA inserts all-gathers at use (overlapped with the period
  scan) and reduce-scatters for gradients. MoE experts shard over ``model``
  (EP); the ``pod`` axis only ever carries gradient/weight collectives so the
  cross-DCN traffic is the slow, overlappable kind.
* ``decode``: weights TP over ``model`` (plus ZeRO-style ``data`` sharding
  when the TP shard would not fit HBM — 398B/400B archs); KV cache shards
  batch over ``data`` and *sequence over model* (flash-decode: per-shard
  partial softmax + tiny cross-shard reduction), which is what lets a 32k
  cache x 128 batch fit and keeps per-token HBM reads balanced.

GQA note: kv projections are *replicated* over ``model`` when
``num_kv_heads < model_parallelism`` (Megatron-style GQA handling) — the
q path carries the TP; kv weights are small.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in fsdp_axes(mesh)]))


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def _kv_tp_ok(cfg: ModelConfig, mesh: Mesh) -> bool:
    return cfg.num_kv_heads % tp_size(mesh) == 0


def param_spec(
    cfg: ModelConfig,
    mesh: Mesh,
    path: str,
    shape: Tuple[int, ...],
    *,
    mode: str,
    zero_shard_decode: bool = False,
) -> P:
    """PartitionSpec for one parameter. ``path`` is '/'-joined pytree path.

    Layer-stack params carry a leading ``num_periods`` axis (never sharded).
    """
    F = fsdp_axes(mesh)  # ('pod','data') or ('data',)
    Mx = "model"
    train = mode in ("train", "prefill")
    # in decode, weights are TP-sharded; optionally ZeRO over data for giants
    Fw: Tuple[str, ...] = F if (train or zero_shard_decode) else ()

    def fs(i: int) -> Optional[Tuple[str, ...]]:
        """fsdp axes if the dim divides, else None (replicated)."""
        if not Fw:
            return None
        d = int(np.prod([mesh.shape[a] for a in Fw]))
        return Fw if shape[i] % d == 0 else None

    def mp(i: int):
        return Mx if shape[i] % tp_size(mesh) == 0 else None

    lead = (None,) if re.search(r"(layers|enc_layers)/", path) else ()
    n = len(shape) - len(lead)

    # --- embeddings
    if path.endswith("embed/embedding"):
        return P(mp(0), fs(1))
    if path.endswith("embed/lm_head"):
        return P(fs(0), mp(1))
    # --- attention
    if re.search(r"mixer/wq$|cross/wq$", path):
        return P(*lead, fs(-2), mp(-1))
    if re.search(r"mixer/w[kv]$|cross/w[kv]$", path):
        kv = Mx if _kv_tp_ok(cfg, mesh) else None
        return P(*lead, fs(-2), kv)
    if re.search(r"mixer/wo$|cross/wo$", path):
        return P(*lead, mp(-2), fs(-1))
    if re.search(r"b[qkv]$", path):
        return P(*lead, None)
    # --- MoE (leading expert axis after the period axis) — check before the
    # dense-mlp patterns, which would otherwise swallow the 3D expert weights
    if re.search(r"ffn/router$", path):
        return P(*lead, fs(-2), None)
    if n == 3 and re.search(r"ffn/w[gud]$", path):  # (E, d_in, d_out)
        e = Mx if shape[len(lead)] % tp_size(mesh) == 0 else None
        return P(*lead, e, fs(-2) if train else None, None)
    # --- dense mlp
    if re.search(r"ffn/w[gu]$|shared/w[gu]$", path):
        return P(*lead, fs(-2), mp(-1))
    if re.search(r"ffn/wd$|shared/wd$", path):
        return P(*lead, mp(-2), fs(-1))
    # --- mamba
    if re.search(r"mixer/in_proj$", path):
        return P(*lead, fs(-2), mp(-1))
    if re.search(r"mixer/out_proj$", path):
        return P(*lead, mp(-2), fs(-1))
    if re.search(r"mixer/conv_[wb]$|mixer/(A_log|D|dt_bias|norm)$", path):
        return P(*lead, *([None] * n))
    # --- norms and everything else: replicated (tiny)
    return P(*lead, *([None] * n))


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shapes: dict, *, mode: str) -> dict:
    """PartitionSpecs for a batch dict (tokens/positions/encoder embeds)."""
    F = fsdp_axes(mesh)
    out = {}
    for k, v in batch_shapes.items():
        shape = v.shape if hasattr(v, "shape") else v
        bdim = int(np.prod([mesh.shape[a] for a in F]))
        b_ax = F if shape[0] % bdim == 0 and shape[0] > 1 else None
        if k == "mrope_positions":  # (3, B, S)
            b2 = F if shape[1] % bdim == 0 and shape[1] > 1 else None
            out[k] = P(None, b2, None)
        elif k == "positions":  # (B,)
            out[k] = P(b_ax)
        else:
            out[k] = P(b_ax, *([None] * (len(shape) - 1)))
    return out


def cache_spec(
    cfg: ModelConfig, mesh: Mesh, path: str, shape: Tuple[int, ...]
) -> P:
    """Decode-cache sharding. Leaves carry a leading num_periods axis.

    k/v: (P, B, L, Hkv, Dh) -> batch over data, **sequence over model**
    (flash-decode); ssm: (P, B, H, hp, N) -> batch over data, heads over
    model; conv: (P, B, W-1, C) -> batch over data, channels over model.
    """
    F = fsdp_axes(mesh)
    bdim = int(np.prod([mesh.shape[a] for a in F]))
    b_ax = F if shape[1] % bdim == 0 and shape[1] > 1 else None
    t = tp_size(mesh)
    if re.search(r"/(k|v|ck|cv)$", path):
        l_ax = "model" if shape[2] % t == 0 else None
        return P(None, b_ax, l_ax, None, None)
    if path.endswith("/ssm"):
        h_ax = "model" if shape[2] % t == 0 else None
        return P(None, b_ax, h_ax, None, None)
    if path.endswith("/conv"):
        c_ax = "model" if shape[3] % t == 0 else None
        return P(None, b_ax, None, c_ax)
    return P(*([None] * len(shape)))


# ---------------------------------------------------------------------------
# tree-level entry points
# ---------------------------------------------------------------------------


def tree_param_specs(cfg: ModelConfig, mesh: Mesh, params_shapes, *, mode: str,
                     zero_shard_decode: bool = False):
    """Map a params pytree (of ShapeDtypeStruct or arrays) to PartitionSpecs."""
    def one(path, leaf):
        return param_spec(
            cfg, mesh, path_str(path), leaf.shape, mode=mode,
            zero_shard_decode=zero_shard_decode,
        )
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def tree_cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shapes):
    def one(path, leaf):
        return cache_spec(cfg, mesh, path_str(path), leaf.shape)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def tree_opt_specs(cfg: ModelConfig, mesh: Mesh, opt_shapes, *, mode: str = "train"):
    """Optimizer state mirrors the param sharding; scalars replicated."""
    def one(path, leaf):
        ps = path_str(path)
        if ps.endswith("step") or leaf.ndim == 0:
            return P()
        # strip the leading 'm/' or 'v/' component so param rules match
        inner = ps.split("/", 1)[1] if "/" in ps else ps
        return param_spec(cfg, mesh, inner, leaf.shape, mode=mode)
    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def needs_zero_decode(cfg: ModelConfig, mesh: Mesh, hbm_bytes: int = 16 << 30) -> bool:
    """True if TP-only weights would overflow ~60% of HBM (398B/400B archs)."""
    bytes_per = 2 if cfg.param_dtype == "bfloat16" else 4
    return cfg.param_count() * bytes_per / tp_size(mesh) > 0.6 * hbm_bytes
