from repro.distributed import rules  # noqa: F401
from repro.distributed.act_sharding import activation_policy, constrain  # noqa: F401
from repro.distributed.compat import abstract_mesh, make_mesh  # noqa: F401
