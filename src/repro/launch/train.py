"""Fault-tolerant training launcher.

Features exercised by tests/test_fault_tolerance.py and examples/train_lm.py:
* auto-resume from the newest atomic checkpoint (restart == recovery);
* per-step wall-time watchdog: an EWMA straggler detector flags steps
  slower than ``straggler_factor`` x the running mean (on real pods this
  triggers hot-spare swap; here it logs + counts);
* deterministic data resume (batch is a pure function of step);
* optional simulated failure injection (``--fail-at-step``) proving the
  restart path end to end;
* elastic rescale: restore() re-device_puts under whatever mesh the new
  incarnation runs (checkpoints are mesh-agnostic full arrays).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt [--resume] [--fail-at-step 20]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.core.slowness import EwmaDetector
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.steps import init_train_state, make_train_step


class StragglerWatchdog:
    """EWMA step-time monitor (the 1000-node version pages the scheduler to
    drain the slow host; the single-process version records the event).

    Thin wrapper over the shared :class:`~repro.core.slowness.EwmaDetector`
    — the serving-side gray-failure detector and the training watchdog
    judge stragglers with the same primitive and thresholds."""

    def __init__(self, factor: float = 2.5, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self._det = EwmaDetector(factor=factor, alpha=alpha)
        self.flagged = []

    @property
    def ewma(self) -> Optional[float]:
        return self._det.ewma

    def observe(self, step: int, dt: float) -> bool:
        baseline = self._det.ewma  # the EWMA this step is judged against
        is_straggler = self._det.observe(dt)
        if is_straggler:
            self.flagged.append((step, dt, baseline))
        return is_straggler


def train_loop(
    arch: str = "qwen2.5-3b",
    *,
    smoke: bool = True,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 64,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 10,
    resume: bool = True,
    fail_at_step: Optional[int] = None,
    microbatches: int = 1,
    lr: float = 1e-3,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                              total_steps=steps)
    data = TokenPipeline(DataConfig(cfg.vocab_size, global_batch, seq_len, seed=seed))
    mgr = CheckpointManager(ckpt_dir, keep=2)

    state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(seed))
    start = 0
    if resume:
        latest, state = mgr.restore_latest(state)
        if latest is not None:
            start = latest
            print(f"[train] resumed from step {latest}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches=microbatches),
                      donate_argnums=(0,))
    watchdog = StragglerWatchdog()
    losses = []
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.monotonic()
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.monotonic() - t0
        if watchdog.observe(step, dt):
            print(f"[watchdog] step {step} straggled: {dt*1e3:.0f}ms "
                  f"(ewma {watchdog.ewma*1e3:.0f}ms)")
        if (step + 1) % ckpt_every == 0 or step + 1 == steps:
            mgr.save(step + 1, state, extra={"loss": loss})
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
    return state, losses, watchdog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-resume", dest="resume", action="store_false")
    ap.add_argument("--fail-at-step", type=int)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    train_loop(
        args.arch, smoke=args.smoke, steps=args.steps,
        global_batch=args.global_batch, seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, fail_at_step=args.fail_at_step,
        microbatches=args.microbatches,
    )


if __name__ == "__main__":
    main()
