"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=512`` *before* importing jax; tests and benches see 1 device.
"""
from __future__ import annotations

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    The ``pod`` axis participates only in FSDP/gradient collectives (DCN-
    friendly); ``data`` is batch/FSDP; ``model`` is TP/EP/flash-decode.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — smoke tests."""
    return make_mesh((data, model), ("data", "model"))
