import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production mesh, print memory/cost analysis, and emit the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the 2x16x16
multi-pod mesh. Nothing else in the repo sets this flag.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--workers 2] \
      [--out artifacts/dryrun]
  python -m repro.launch.dryrun --all --both-meshes   # full 40x2 matrix

``--all`` fans cells out as subprocesses (isolation: one cell's failure or
OOM cannot poison the rest; results land as JSON per cell).
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.analysis.hlo_analysis import analyze_compiled
from repro.analysis.roofline import roofline_from_report
from repro.configs import ARCHS, SHAPES, get_arch, get_shape, shape_applicable


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    """Lower + compile one cell; return the full analysis record."""
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    jitted, args, meta = build_cell(arch, shape_name, mesh)
    from repro.distributed.act_sharding import activation_policy

    with mesh:
        with activation_policy(meta.get("policy")):
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    # memory_analysis proves the program fits; cost/collective terms feed
    # the roofline (scan-aware parse; see analysis/hlo_analysis.py).
    report = analyze_compiled(compiled)
    mem = report.get("memory", {})
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis:", mem)
    print(f"[{arch} x {shape_name} x {rec['mesh']}] cost_analysis:",
          report.get("xla_cost_analysis"))
    rec.update(
        status="OK",
        mode=meta["mode"],
        tokens_per_step=meta["tokens_per_step"],
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        analysis=report,
        roofline=roofline_from_report(
            cfg, report, chips=rec["chips"], mode=meta["mode"],
            tokens=meta["tokens_per_step"],
        ),
    )
    return rec


def _cell_cmd(arch, shape, multi_pod, out_path):
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--json-out", str(out_path),
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    return cmd


def run_all(multi_pod_options, out_dir: Path, workers: int, archs=None, shapes=None):
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = []
    for mp in multi_pod_options:
        for a in (archs or ARCHS):
            for s in (shapes or SHAPES):
                tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
                cells.append((a, s, mp, out_dir / f"{tag}.json"))
    procs: list = []
    pending = list(cells)
    results = {}
    while pending or procs:
        while pending and len(procs) < workers:
            a, s, mp, path = pending.pop(0)
            if path.exists():  # incremental: reuse finished cells
                results[path.name] = json.loads(path.read_text())
                print(f"cached   {path.stem}")
                continue
            log = open(path.with_suffix(".log"), "w")
            p = subprocess.Popen(
                _cell_cmd(a, s, mp, path), stdout=log, stderr=subprocess.STDOUT,
                cwd=str(Path(__file__).resolve().parents[3]),
                env={**os.environ, "PYTHONPATH": "src"},
            )
            procs.append((p, a, s, mp, path, log, time.time()))
        for item in procs[:]:
            p, a, s, mp, path, log, t0 = item
            rc = p.poll()
            if rc is None:
                if time.time() - t0 > 3600:
                    p.kill()
                    rc = -9
                else:
                    continue
            procs.remove(item)
            log.close()
            if rc == 0 and path.exists():
                results[path.name] = json.loads(path.read_text())
                st = results[path.name].get("status")
                print(f"done     {path.stem}: {st}")
            else:
                rec = {"arch": a, "shape": s, "status": "FAIL", "rc": rc,
                       "mesh": "2x16x16" if mp else "16x16",
                       "log": str(path.with_suffix(".log"))}
                path.write_text(json.dumps(rec))
                results[path.name] = rec
                print(f"FAILED   {path.stem} rc={rc} (log: {rec['log']})")
        time.sleep(0.5)
    # summary
    n_ok = sum(1 for r in results.values() if r.get("status") == "OK")
    n_skip = sum(1 for r in results.values() if r.get("status") == "SKIP")
    n_fail = sum(1 for r in results.values() if r.get("status") == "FAIL")
    print(f"\n=== dry-run matrix: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"of {len(results)} cells ===")
    (out_dir / "summary.json").write_text(json.dumps(list(results.values()), indent=1))
    return 1 if n_fail else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--json-out")
    args = ap.parse_args()

    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        sys.exit(run_all(meshes, Path(args.out), args.workers))

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    js = json.dumps(rec, indent=1, default=str)
    if args.json_out:
        Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json_out).write_text(js)
    print(js)
    if rec["status"] == "FAIL":
        sys.exit(1)


if __name__ == "__main__":
    main()
