"""``input_specs``: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the same pattern the
dry-run, roofline harness, and launcher all consume. The modality frontends
(whisper mel conv, qwen2-vl vision tower) are STUBS per the assignment:
their outputs (frame/patch embeddings, M-RoPE position ids) appear here as
precomputed inputs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def batch_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs for a train or prefill step (full-sequence)."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if shape.kind == "train":
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    if cfg.mrope_sections:
        specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, enc_len_for(cfg, S), cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs for one serve_step: a new token per sequence + its position.

    The KV/SSM cache is passed separately (see ``serving.cache_shapes``)."""
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Whisper conv frontend downsamples mel frames 2x -> S_enc = S // 2."""
    return max(seq_len // 2, 1)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    return batch_input_specs(cfg, shape)
