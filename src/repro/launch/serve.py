"""Serving launcher: the unified gateway fronting real (reduced) models —
the serving-side end-to-end driver.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --system sage --requests 32 --rate 8
"""
from __future__ import annotations

import argparse
import time

from repro.api import FunctionSpec, Gateway, PoissonWorkload


def serve(
    arch: str = "qwen2.5-3b",
    system: str = "sage",
    *,
    requests: int = 32,
    rate: float = 8.0,
    profile: str = "resnet50",
    time_scale: float = 0.2,
    seed: int = 0,
):
    gw = Gateway(backend="runtime", policy=system, time_scale=time_scale,
                 exit_ttl=5.0)
    gw.register(FunctionSpec(name=f"{arch}-fn", arch=arch, profile=profile))
    workload = PoissonWorkload(f"{arch}-fn", rate,
                               duration_s=4.0 * requests / rate, seed=seed,
                               max_events=requests)
    t0 = time.monotonic()
    tel = gw.replay(workload, seed=seed)
    wall = time.monotonic() - t0
    n = len(workload)
    print(f"[serve:{system}] {n} requests in {wall:.2f}s "
          f"({n/wall:.2f}/s) mean={tel.mean_e2e()*1e3:.1f}ms "
          f"p99={tel.p99_e2e()*1e3:.1f}ms warm%={tel.warm_fraction()*100:.0f} "
          f"shared_hits={gw.runtime.daemon.stats['shared_hits']}")
    gw.shutdown()
    return tel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--system", default="sage")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--profile", default="resnet50")
    args = ap.parse_args()
    serve(args.arch, args.system, requests=args.requests, rate=args.rate,
          profile=args.profile)


if __name__ == "__main__":
    main()
