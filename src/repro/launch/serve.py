"""Serving launcher: SAGE runtime fronting real (reduced) models with
batched decoding — the serving-side end-to-end driver.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --system sage --requests 32 --rate 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import SageRuntime
from repro.core.functions import make_model_function, make_request
from repro.core.profiles import PROFILES


def serve(
    arch: str = "qwen2.5-3b",
    system: str = "sage",
    *,
    requests: int = 32,
    rate: float = 8.0,
    profile: str = "resnet50",
    time_scale: float = 0.2,
    seed: int = 0,
):
    rt = SageRuntime(system, time_scale=time_scale, exit_ttl=5.0)
    rt.sage_init()
    fn = make_model_function(rt.db, f"{arch}-fn", arch=arch,
                             profile=PROFILES[profile])
    rt.register_function(fn)
    rng = np.random.default_rng(seed)
    futs = []
    t0 = time.monotonic()
    for i in range(requests):
        futs.append(rt.submit(make_request(rt.db, fn, seed=seed + i)))
        time.sleep(rng.exponential(1.0 / rate))
    for f in futs:
        f.result(timeout=120)
    wall = time.monotonic() - t0
    tel = rt.telemetry
    print(f"[serve:{system}] {requests} requests in {wall:.2f}s "
          f"({requests/wall:.2f}/s) mean={tel.mean_e2e()*1e3:.1f}ms "
          f"p99={tel.p99_e2e()*1e3:.1f}ms warm%={tel.warm_fraction()*100:.0f} "
          f"shared_hits={rt.daemon.stats['shared_hits']}")
    rt.shutdown()
    return tel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--system", default="sage")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--profile", default="resnet50")
    args = ap.parse_args()
    serve(args.arch, args.system, requests=args.requests, rate=args.rate,
          profile=args.profile)


if __name__ == "__main__":
    main()
