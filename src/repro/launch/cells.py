"""Dry-run cell construction: build the jitted step + abstract inputs +
shardings for one (arch x shape x mesh) cell. Shared by dryrun.py and the
roofline benchmark so the analyzed program IS the launch program.

Per-cell tuning knobs (microbatches, activation layout, decode ZeRO) live in
``CELL_TUNING`` — entries here are the outcomes of the §Perf hillclimb loop
recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, get_arch, get_shape, shape_applicable
from repro.distributed import rules
from repro.distributed.act_sharding import activation_policy
from repro.launch.specs import batch_input_specs, decode_input_specs, enc_len_for
from repro.serving.engine import cache_shapes, make_decode_step, make_prefill_step
from repro.training.optimizer import OptimizerConfig
from repro.training.steps import init_train_state, make_train_step


@dataclass
class CellTuning:
    microbatches: int = 1
    # activation residual layout during train/prefill: 'model' shards d_model
    # over the model axis (Megatron-style), 'none' keeps it replicated on TP
    residual: str = "model"
    remat: Optional[str] = None  # override cfg.remat_policy
    opt_state_dtype: Optional[str] = None


# §Perf outcomes (see EXPERIMENTS.md). Key: (arch, shape) or (arch, None).
CELL_TUNING: Dict[Tuple[str, Optional[str]], CellTuning] = {
    ("llama4-maverick-400b-a17b", "train_4k"): CellTuning(
        opt_state_dtype="bfloat16", microbatches=4),
    ("jamba-1.5-large-398b", "train_4k"): CellTuning(
        opt_state_dtype="bfloat16", microbatches=4),  # §Perf: fit 151->60 GB temp
    ("qwen2-vl-72b", "train_4k"): CellTuning(
        opt_state_dtype="float32", microbatches=2),
}


def get_tuning(arch: str, shape: str) -> CellTuning:
    return CELL_TUNING.get((arch, shape)) or CELL_TUNING.get((arch, None)) or CellTuning()


def _opt_cfg(cfg: ModelConfig, tuning: CellTuning) -> OptimizerConfig:
    dt = tuning.opt_state_dtype or (
        "bfloat16" if cfg.param_count() > 1e11 else "float32"
    )
    return OptimizerConfig(state_dtype=dt)


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def _apply_remat(cfg: ModelConfig, tuning: CellTuning) -> ModelConfig:
    import dataclasses

    if tuning.remat and tuning.remat != cfg.remat_policy:
        return dataclasses.replace(cfg, remat_policy=tuning.remat)
    return cfg


def build_cell(arch: str, shape_name: str, mesh: Mesh):
    """Returns (jitted_fn, abstract_args tuple, meta dict) ready to lower.

    Raises ValueError for inapplicable cells (see shape_applicable)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"SKIP: {reason}")
    tuning = get_tuning(arch, shape_name)
    cfg = _apply_remat(cfg, tuning)
    F = rules.fsdp_axes(mesh)

    if shape.kind == "train":
        return _build_train(cfg, shape, mesh, tuning)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh, tuning)
    return _build_decode(cfg, shape, mesh, tuning)


def _build_train(cfg, shape, mesh, tuning):
    opt_cfg = _opt_cfg(cfg, tuning)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    )
    pspecs = rules.tree_param_specs(cfg, mesh, state_shapes["params"], mode="train")
    ospecs = rules.tree_opt_specs(cfg, mesh, state_shapes["opt"])
    state_specs = {"params": pspecs, "opt": ospecs}
    batch_shapes = batch_input_specs(cfg, shape)
    bspecs = rules.batch_specs(cfg, mesh, batch_shapes, mode="train")

    F = rules.fsdp_axes(mesh)
    pol = {"residual": P(F, None, "model" if tuning.residual == "model" else None)}

    step = make_train_step(cfg, opt_cfg, microbatches=tuning.microbatches)
    jitted = jax.jit(
        step,
        in_shardings=(_named(mesh, state_specs), _named(mesh, bspecs)),
        out_shardings=(_named(mesh, state_specs), None),
        donate_argnums=(0,),
    )
    meta = {
        "mode": "train",
        "tokens_per_step": shape.global_batch * shape.seq_len,
        "policy": pol,
        "opt_state_dtype": opt_cfg.state_dtype,
        "microbatches": tuning.microbatches,
    }
    return jitted, (state_shapes, batch_shapes), meta


def _build_prefill(cfg, shape, mesh, tuning):
    from repro.models import init_params

    params_shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = rules.tree_param_specs(cfg, mesh, params_shapes, mode="prefill")
    batch_shapes = batch_input_specs(cfg, shape)
    bspecs = rules.batch_specs(cfg, mesh, batch_shapes, mode="prefill")
    enc_len = enc_len_for(cfg, shape.seq_len) if cfg.is_encoder_decoder else 0
    cshape = cache_shapes(cfg, shape.global_batch, shape.seq_len, enc_len)
    cspecs = rules.tree_cache_specs(cfg, mesh, cshape)

    F = rules.fsdp_axes(mesh)
    pol = {"residual": P(F, None, "model" if tuning.residual == "model" else None)}
    logits_spec = P(F if shape.global_batch > 1 else None, None)

    step = make_prefill_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(
            _named(mesh, pspecs), _named(mesh, bspecs), _named(mesh, cspecs),
        ),
        out_shardings=(NamedSharding(mesh, logits_spec), _named(mesh, cspecs)),
        donate_argnums=(2,),
    )
    meta = {
        "mode": "prefill",
        "tokens_per_step": shape.global_batch * shape.seq_len,
        "policy": pol,
    }
    return jitted, (params_shapes, batch_shapes, cshape), meta


def _build_decode(cfg, shape, mesh, tuning):
    from repro.models import init_params

    zero = rules.needs_zero_decode(cfg, mesh)
    params_shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = rules.tree_param_specs(
        cfg, mesh, params_shapes, mode="decode", zero_shard_decode=zero
    )
    B = shape.global_batch
    enc_len = enc_len_for(cfg, shape.seq_len) if cfg.is_encoder_decoder else 0
    cshape = cache_shapes(cfg, B, shape.seq_len, enc_len)
    cspecs = rules.tree_cache_specs(cfg, mesh, cshape)

    F = rules.fsdp_axes(mesh)
    bdim = rules.dp_size(mesh)
    b_ax = F if (B % bdim == 0 and B > 1) else None
    tok_spec = P(b_ax, None)
    pos_spec = P(b_ax)
    logits_spec = P(b_ax, None)
    pol = {"residual": P(b_ax, None, None)}

    step = make_decode_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(
            _named(mesh, pspecs),
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, pos_spec),
            _named(mesh, cspecs),
        ),
        out_shardings=(NamedSharding(mesh, logits_spec), _named(mesh, cspecs)),
        donate_argnums=(3,),
    )
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    meta = {
        "mode": "decode",
        "tokens_per_step": B,
        "policy": pol,
        "zero_shard_decode": zero,
    }
    return jitted, (params_shapes, tok, pos, cshape), meta
