"""Model facade: init / forward / prefill / decode for every assigned family.

Layer stacks are scanned over *periods* (see ``configs.base``): params and
decode caches carry a leading ``num_periods`` axis and are consumed with
``lax.scan``, so the HLO is depth-independent (fast 512-device AOT compiles)
and XLA can overlap the FSDP all-gather of period *i+1* with compute of
period *i*.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, SublayerSpec
from repro.distributed.act_sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(cfg: ModelConfig, spec: SublayerSpec, key, *, cross: bool) -> dict:
    ks = jax.random.split(key, 4)
    dt = L.pdtype(cfg)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if spec.mixer == "attn":
        p["mixer"] = L.init_attention(cfg, ks[0])
    else:
        p["mixer"] = M.init_mamba(cfg, ks[0])
    if cross:
        p["cross"] = L.init_attention(cfg, ks[1])
        p["norm_cross"] = jnp.ones((cfg.d_model,), dt)
    if spec.ffn == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = L.init_mlp(cfg, ks[2])
    elif spec.ffn == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = L.init_moe(cfg, ks[3])
    return p


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _init_stack(cfg: ModelConfig, key, num_periods: int, specs, *, cross: bool) -> dict:
    """Stacked per-period params: {'sub{i}': pytree with leading period axis}."""
    out = {}
    for i, spec in enumerate(specs):
        ks = jax.random.split(jax.random.fold_in(key, i), num_periods)
        out[f"sub{i}"] = _stack([_init_sublayer(cfg, spec, k, cross=cross) for k in ks])
    return out


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    dt = L.pdtype(cfg)
    params: Params = {"embed": L.init_embed(cfg, ks[0])}
    params["layers"] = _init_stack(
        cfg, ks[1], cfg.num_periods, cfg.period_spec(), cross=cfg.is_encoder_decoder
    )
    params["final_norm"] = jnp.ones((cfg.d_model,), dt)
    if cfg.is_encoder_decoder:
        enc_spec = (SublayerSpec(mixer="attn", ffn="dense"),)
        params["enc_layers"] = _init_stack(cfg, ks[2], cfg.encoder_layers, enc_spec, cross=False)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dt)
    return params


# ---------------------------------------------------------------------------
# sublayer application
# ---------------------------------------------------------------------------


def _apply_sublayer_full(
    cfg: ModelConfig,
    spec: SublayerSpec,
    p: dict,
    h: jax.Array,
    *,
    positions,
    causal: bool,
    enc: Optional[jax.Array],
    cache: Optional[dict],
    mode: str,  # 'train' | 'prefill'
):
    """Full-sequence sublayer. Returns (h, new_cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    x = L.rms_norm(h, p["norm1"], cfg.rmsnorm_eps)
    if spec.mixer == "attn":
        if mode == "prefill" and cache is not None:
            y, (k, v) = L.attention_forward(cfg, p["mixer"], x, positions, causal=causal, return_kv=True)
            S = x.shape[1]
            new_cache["k"] = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            new_cache["v"] = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        else:
            y = L.attention_forward(cfg, p["mixer"], x, positions, causal=causal)
    else:
        if mode == "prefill" and cache is not None:
            y, fs, conv_tail = M.mamba_forward(cfg, p["mixer"], x, return_state=True)
            new_cache["ssm"] = fs.astype(cache["ssm"].dtype)
            new_cache["conv"] = conv_tail.astype(cache["conv"].dtype)
        else:
            y = M.mamba_forward(cfg, p["mixer"], x)
    h = h + y.astype(h.dtype)

    if "cross" in p and enc is not None:
        xc = L.rms_norm(h, p["norm_cross"], cfg.rmsnorm_eps)
        if mode == "prefill" and cache is not None:
            yc, (ck, cv) = _cross_with_kv(cfg, p["cross"], xc, enc)
            new_cache["ck"] = ck.astype(cache["ck"].dtype)
            new_cache["cv"] = cv.astype(cache["cv"].dtype)
        else:
            yc = L.cross_attention_forward(cfg, p["cross"], xc, enc)
        h = h + yc.astype(h.dtype)

    if spec.ffn != "none":
        x2 = L.rms_norm(h, p["norm2"], cfg.rmsnorm_eps)
        if spec.ffn == "dense":
            f = L.mlp_forward(cfg, p["ffn"], x2)
        else:
            f, aux = L.moe_forward(cfg, p["ffn"], x2)
        h = h + f.astype(h.dtype)
    return h, new_cache, aux


def _cross_with_kv(cfg, p, x, enc):
    q, k, v = L._project_qkv(cfg, p, x, enc)
    out = L.flash_attention_ref(q, k, v, causal=False)
    return L._out_proj(cfg, p, out), (k, v)


def _apply_sublayer_step(
    cfg: ModelConfig,
    spec: SublayerSpec,
    p: dict,
    h: jax.Array,          # (B, 1, D)
    cache: dict,
    *,
    positions: jax.Array,  # (B,)
):
    """One-token decode sublayer. Returns (h, new_cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache)
    x = L.rms_norm(h, p["norm1"], cfg.rmsnorm_eps)
    if spec.mixer == "attn":
        pos = jnp.broadcast_to(positions, (3,) + positions.shape) if cfg.mrope_sections else positions
        y, nk, nv = L.attention_decode(cfg, p["mixer"], x, pos, cache["k"], cache["v"])
        new_cache["k"], new_cache["v"] = nk, nv
    else:
        y, ns, ncv = M.mamba_decode(cfg, p["mixer"], x, cache["ssm"], cache["conv"])
        new_cache["ssm"], new_cache["conv"] = ns.astype(cache["ssm"].dtype), ncv
    h = h + y.astype(h.dtype)

    if "cross" in p:
        xc = L.rms_norm(h, p["norm_cross"], cfg.rmsnorm_eps)
        q, _, _ = L._project_qkv(cfg, p["cross"], xc, xc)
        out = L.decode_attention_ref(
            q,
            cache["ck"],
            cache["cv"],
            jnp.full((h.shape[0],), cache["ck"].shape[1], jnp.int32),
        )
        h = h + L._out_proj(cfg, p["cross"], out).astype(h.dtype)

    if spec.ffn != "none":
        x2 = L.rms_norm(h, p["norm2"], cfg.rmsnorm_eps)
        if spec.ffn == "dense":
            f = L.mlp_forward(cfg, p["ffn"], x2)
        else:
            B = x2.shape[0]
            f, aux = L.moe_forward(
                cfg, p["ffn"], x2.reshape(1, B, -1), capacity_factor=4.0
            )
            f = f.reshape(B, 1, -1)
        h = h + f.astype(h.dtype)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# remat policy
# ---------------------------------------------------------------------------


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots_saveable":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _run_stack_full(
    cfg: ModelConfig,
    stack_params: dict,
    specs,
    h: jax.Array,
    *,
    positions,
    causal: bool,
    enc: Optional[jax.Array] = None,
    cache_stack: Optional[dict] = None,
    mode: str = "train",
):
    """Scan the period stack over a full sequence. Returns (h, new_cache, aux)."""

    def period_fn(carry, xs):
        h, aux = carry
        if cache_stack is not None:
            pp, cc = xs
        else:
            pp, cc = xs, None
        new_cc = {}
        for i, spec in enumerate(specs):
            ci = cc[f"sub{i}"] if cc is not None else None
            h, nci, a = _apply_sublayer_full(
                cfg, spec, pp[f"sub{i}"], h,
                positions=positions, causal=causal, enc=enc, cache=ci, mode=mode,
            )
            aux = aux + a
            if cc is not None:
                new_cc[f"sub{i}"] = {**ci, **nci}
        h = constrain(h, "residual")  # scan-carry layout (saved for backward)
        return (h, aux), new_cc if cache_stack is not None else 0

    period_fn = _maybe_remat(cfg, period_fn)
    xs = (stack_params, cache_stack) if cache_stack is not None else stack_params
    (h, aux), new_cache = lax.scan(period_fn, (h, jnp.zeros((), jnp.float32)), xs)
    return h, (new_cache if cache_stack is not None else None), aux


def _run_stack_step(cfg: ModelConfig, stack_params: dict, specs, h, cache_stack, *, positions):
    def period_fn(carry, xs):
        h, aux = carry
        pp, cc = xs
        new_cc = {}
        for i, spec in enumerate(specs):
            h, nci, a = _apply_sublayer_step(
                cfg, spec, pp[f"sub{i}"], h, cc[f"sub{i}"], positions=positions
            )
            aux = aux + a
            new_cc[f"sub{i}"] = nci
        return (h, aux), new_cc

    (h, aux), new_cache = lax.scan(
        period_fn, (h, jnp.zeros((), jnp.float32)), (stack_params, cache_stack)
    )
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _encode(cfg: ModelConfig, params: Params, enc_embeds: jax.Array) -> jax.Array:
    """Whisper encoder: non-causal self-attention over frame embeddings."""
    S = enc_embeds.shape[1]
    pos = jnp.arange(S)[None, :]
    enc_spec = (SublayerSpec(mixer="attn", ffn="dense"),)
    h = enc_embeds.astype(L.cdtype(cfg))
    h, _, _ = _run_stack_full(
        cfg, params["enc_layers"], enc_spec, h, positions=pos, causal=cfg.encoder_causal
    )
    return L.rms_norm(h, params["enc_final_norm"], cfg.rmsnorm_eps)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    """Training/eval forward. Returns (logits (B,S,V) fp32, aux_loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.mrope_sections:
        positions = batch.get("mrope_positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    else:
        positions = jnp.arange(S)[None, :]
    enc = None
    if cfg.is_encoder_decoder:
        enc = _encode(cfg, params, batch["encoder_embeds"])
    h = L.embed(cfg, params["embed"], tokens)
    h, _, aux = _run_stack_full(
        cfg, params["layers"], cfg.period_spec(), h,
        positions=positions, causal=cfg.causal, enc=enc,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
    logits = L.unembed(cfg, params["embed"], h)
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0) -> Cache:
    """Concrete zero-filled decode cache (leading num_periods axis per leaf)."""
    P = cfg.num_periods
    dt = L.cdtype(cfg)
    cache: Cache = {}
    for i, spec in enumerate(cfg.period_spec()):
        entry: dict = {}
        if spec.mixer == "attn":
            kv = (P, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            entry["k"] = jnp.zeros(kv, dt)
            entry["v"] = jnp.zeros(kv, dt)
        else:
            entry["ssm"] = jnp.zeros(
                (P, batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
            )
            entry["conv"] = jnp.zeros((P, batch, cfg.ssm_conv - 1, cfg.ssm_conv_dim), dt)
        if cfg.is_encoder_decoder:
            ckv = (P, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
            entry["ck"] = jnp.zeros(ckv, dt)
            entry["cv"] = jnp.zeros(ckv, dt)
        cache[f"sub{i}"] = entry
    return cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array], cache: Cache):
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B,V), cache, aux).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.mrope_sections:
        positions = batch.get("mrope_positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    else:
        positions = jnp.arange(S)[None, :]
    enc = None
    if cfg.is_encoder_decoder:
        enc = _encode(cfg, params, batch["encoder_embeds"])
    h = L.embed(cfg, params["embed"], tokens)
    h, cache, aux = _run_stack_full(
        cfg, params["layers"], cfg.period_spec(), h,
        positions=positions, causal=cfg.causal, enc=enc,
        cache_stack=cache, mode="prefill",
    )
    h = L.rms_norm(h[:, -1:], params["final_norm"], cfg.rmsnorm_eps)
    logits = L.unembed(cfg, params["embed"], h)[:, 0]
    return logits, cache, aux


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,     # (B, 1)
    positions: jax.Array,  # (B,) absolute position of the new token
    cache: Cache,
):
    """One decode step. Returns (logits (B,V) fp32, new cache)."""
    h = L.embed(cfg, params["embed"], tokens)
    h, cache, _ = _run_stack_step(
        cfg, params["layers"], cfg.period_spec(), h, cache, positions=positions
    )
    h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
    logits = L.unembed(cfg, params["embed"], h)[:, 0]
    return logits, cache
