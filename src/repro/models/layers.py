"""Shared model layers (pure functional JAX).

Conventions
-----------
* params are nested dicts of jnp arrays; layer stacks carry a leading
  ``num_periods`` axis and are consumed via ``lax.scan``.
* weights live in ``cfg.param_dtype``; matmuls run in ``cfg.compute_dtype``
  with fp32 softmax/norm/accumulation.
* attention is *blockwise* (flash-style, online softmax) in pure jnp so that
  32k-token prefill never materialises an (S, S) score tensor and causal
  FLOPs are exact (static python loop over query blocks -> each block attends
  only to its prefix). The Pallas kernel in ``repro.kernels`` implements the
  same contract for TPU; ``repro.kernels.ops`` picks the backend.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# small utilities
# ---------------------------------------------------------------------------


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def _uniform(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return _uniform(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


# ---------------------------------------------------------------------------
# RoPE (incl. M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, ...]
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): ``positions`` is (3, ..., S); the half-dim
    frequency bands are split into ``sections`` (t, h, w), each rotated by its
    own position stream."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # select which position stream drives each frequency band
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,)
    pos = positions.astype(jnp.float32)  # (3, ..., S)
    pos_per_band = jnp.take(pos, sec_id, axis=0)  # (half, ..., S) via axis move
    pos_per_band = jnp.moveaxis(pos_per_band, 0, -1)  # (..., S, half)
    angles = pos_per_band * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure jnp, exact causal FLOPs
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_block(q, k, v, m, l, acc, *, scale, mask=None):
    """One online-softmax update. q:(B,cq,H,G,Dh) k,v:(B,ck,H,Dh).

    m,l: (B,cq,H,G) fp32 running max / normaliser; acc: (B,cq,H,G,Dh) fp32.
    """
    s = jnp.einsum(
        "bqhgd,bkhd->bqhgk", q, k, preferred_element_type=jnp.float32
    ) * scale  # (B,cq,H,G,ck)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def _softmax_partial(qg, kj, vj, *, scale, mask=None):
    """Dense softmax partial over one kv span. qg:(B,cq,H,G,Dh),
    kj/vj:(B,ck,H,Dh) -> (m, l, acc) fp32."""
    s = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg, kj, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum(
        "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj,
        preferred_element_type=jnp.float32,
    )
    return m, l, acc


def _combine_partials(parts):
    """Merge online-softmax partials [(m,l,acc), ...] -> output fp32."""
    m = parts[0][0]
    for mp_, _, _ in parts[1:]:
        m = jnp.maximum(m, mp_)
    l = jnp.zeros_like(m)
    acc = jnp.zeros(parts[0][2].shape, jnp.float32)
    for mi, li, ai in parts:
        c = jnp.exp(mi - m)
        l = l + li * c
        acc = acc + ai * c[..., None]
    return acc / jnp.maximum(l, 1e-30)[..., None]


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,  # (B, Sk, Hkv, Dh)
    *,
    causal: bool,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """Blockwise attention with online softmax; GQA folded into a group dim.

    Causal mode uses a *static* python loop over query blocks; each block is
    decomposed into a mask-free *prefix rectangle* (one dense matmul over
    kv[0 : i*block_q]) plus a masked *diagonal block*, combined with one
    2-way online-softmax merge. The lowered HLO carries the exact triangular
    FLOP count (matters for the roofline, EXPERIMENTS.md §Perf) and — unlike
    per-block variable-length scans — never tickles the XLA SPMD
    partitioner's dynamic-slice/transpose bug at 256+ devices.

    Non-causal mode scans fixed-size kv blocks with online softmax (memory
    O(Sq x block_k)).
    """
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, Hkv, G, Dh)

    if not causal:
        return _attention_scan_kv(qg, k, v, scale=scale, block_k=block_k
                                  ).reshape(B, Sq, Hq, Dh).astype(q.dtype)

    block_q = min(block_q, Sq)
    pad_q = (-Sq) % block_q
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    n_q = (Sq + pad_q) // block_q

    outs = []
    for i in range(n_q):  # static loop: exact causal prefix per q block
        qi = qg[:, i * block_q : (i + 1) * block_q]
        lo = q_offset + i * block_q           # first q position of the block
        hi = lo + block_q                     # one past last q position
        parts = []
        if lo > 0:  # prefix rectangle: fully visible, no mask needed
            parts.append(_softmax_partial(qi, k[:, :lo], v[:, :lo], scale=scale))
        # diagonal block: causal mask within [lo, min(hi, Sk))
        d_hi = min(hi, Sk)
        if d_hi > lo:
            kd, vd = k[:, lo:d_hi], v[:, lo:d_hi]
            q_pos = lo + jnp.arange(block_q)
            kv_pos = lo + jnp.arange(d_hi - lo)
            mask = q_pos[None, :, None, None, None] >= kv_pos[None, None, None, None, :]
            parts.append(_softmax_partial(qi, kd, vd, scale=scale, mask=mask))
        out = _combine_partials(parts)
        outs.append(out)
    out = jnp.concatenate(outs, axis=1)[:, :Sq]
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _attention_scan_kv(qg, k, v, *, scale, block_k):
    """Non-causal: fixed-length scan over kv blocks with online softmax."""
    B, Sq, Hkv, G, Dh = qg.shape
    Sk = k.shape[1]
    block_k = min(block_k, Sk)
    pad_k = (-Sk) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    n_k = (Sk + pad_k) // block_k
    kb = k.reshape(B, n_k, block_k, Hkv, Dh).swapaxes(0, 1)
    vb = v.reshape(B, n_k, block_k, Hkv, Dh).swapaxes(0, 1)
    k_valid = (jnp.arange(n_k * block_k) < Sk).reshape(n_k, block_k)

    m = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc = jnp.zeros((B, Sq, Hkv, G, Dh), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, valid = xs
        mask = valid[None, None, None, None, :]
        m, l, acc = _attn_block(qg, kj, vj, m, l, acc, scale=scale, mask=mask)
        return (m, l, acc), None

    (m, l, acc), _ = lax.scan(body, (m, l, acc), (kb, vb, k_valid))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def decode_attention_ref(
    q: jax.Array,        # (B, 1, Hq, Dh)
    k_cache: jax.Array,  # (B, L, Hkv, Dh)
    v_cache: jax.Array,  # (B, L, Hkv, Dh)
    lengths: jax.Array,  # (B,) valid cache length per sequence (incl. new token)
    *,
    scale: Optional[float] = None,
    block_k: int = 1024,
) -> jax.Array:
    """Single-token flash-decode: online softmax over KV blocks with per-seq
    length masking. Returns (B, 1, Hq, Dh).

    §Perf note (EXPERIMENTS.md §Perf, iterations 1-2): with the cache
    sequence-sharded over the ``model`` axis, any block-scan that
    ``dynamic_slice``s the L dimension forces the SPMD partitioner into
    involuntary full rematerialization — it *replicates the entire cache
    per layer* ("[SPMD] Involuntary full rematerialization" warnings; the
    HLO roofline showed 60x decode HBM inflation). For Sq=1 the fp32 score
    tensor is only (B, Hq, L) ~ 2 MB/shard, so the optimal XLA formulation
    is one dense masked pass: scores stay L-sharded, the softmax reduce and
    the p@V contraction partial-reduce over shards (flash-decode across
    devices for free). The VMEM-blocked structure lives in the Pallas
    kernel (``repro.kernels.decode_attention``), where it belongs.
    ``block_k`` is kept for API compatibility (the Pallas kernel uses it).
    """
    B, _, Hq, Dh = q.shape
    _, L, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, 1, Hkv, G, Dh)

    s = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B,1,Hkv,G,L) fp32, L stays sharded
    mask = jnp.arange(L)[None, None, None, None, :] < \
        lengths[:, None, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1)
    acc = jnp.einsum(
        "bqhgk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key, *, cross: bool = False) -> dict:
    dt = pdtype(cfg)
    d, dh = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * dh, dt),
        "wk": dense_init(ks[1], d, nkv * dh, dt),
        "wv": dense_init(ks[2], d, nkv * dh, dt),
        "wo": dense_init(ks[3], nq * dh, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * dh,), dt)
        p["bk"] = jnp.zeros((nkv * dh,), dt)
        p["bv"] = jnp.zeros((nkv * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, kv_x: jax.Array):
    B, S, _ = x.shape
    Skv = kv_x.shape[1]
    nq, nkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ct = cdtype(cfg)
    q = jnp.einsum("bsd,de->bse", x.astype(ct), p["wq"].astype(ct))
    k = jnp.einsum("bsd,de->bse", kv_x.astype(ct), p["wk"].astype(ct))
    v = jnp.einsum("bsd,de->bse", kv_x.astype(ct), p["wv"].astype(ct))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(ct)
        k = k + p["bk"].astype(ct)
        v = v + p["bv"].astype(ct)
    q = q.reshape(B, S, nq, dh)
    k = k.reshape(B, Skv, nkv, dh)
    v = v.reshape(B, Skv, nkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rms_norm(k, p["k_norm"], cfg.rmsnorm_eps)
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, positions):
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_forward(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill). positions: (B, S) or
    (3, B, S) for M-RoPE."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, x)
    q, k = _rope_qk(cfg, q, k, positions)
    out = flash_attention_ref(q, k, v, causal=causal)
    y = _out_proj(cfg, p, out)
    if return_kv:
        return y, (k, v)
    return y


def _out_proj(cfg: ModelConfig, p: dict, out: jax.Array) -> jax.Array:
    B, S = out.shape[:2]
    ct = cdtype(cfg)
    flat = out.reshape(B, S, cfg.num_heads * cfg.head_dim).astype(ct)
    return jnp.einsum("bse,ed->bsd", flat, p["wo"].astype(ct))


def cross_attention_forward(
    cfg: ModelConfig, p: dict, x: jax.Array, enc: jax.Array
) -> jax.Array:
    """Cross attention (whisper decoder): queries from x, kv from encoder
    output. No RoPE on cross path."""
    q, k, v = _project_qkv(cfg, p, x, enc)
    out = flash_attention_ref(q, k, v, causal=False)
    return _out_proj(cfg, p, out)


def attention_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,          # (B, 1, D)
    positions: jax.Array,  # (B,) or (3, B) for mrope
    k_cache: jax.Array,    # (B, L, Hkv, Dh)
    v_cache: jax.Array,
):
    """One-token decode: rope at ``positions``, scatter new kv into the cache
    at ``positions``, flash-decode against the cache."""
    B = x.shape[0]
    if cfg.mrope_sections:
        pos_rope = positions[..., None]  # (3, B, 1)
        scatter_pos = positions[0]
    else:
        pos_rope = positions[:, None]  # (B, 1)
        scatter_pos = positions
    q, k, v = _project_qkv(cfg, p, x, x)
    q, k = _rope_qk(cfg, q, k, pos_rope)
    # scatter the new token's kv at per-sequence positions
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, scatter_pos].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, scatter_pos].set(v[:, 0].astype(v_cache.dtype))
    lengths = scatter_pos + 1
    out = decode_attention_ref(q, k_cache, v_cache, lengths)
    return _out_proj(cfg, p, out), k_cache, v_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    dt = pdtype(cfg)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], d, f, dt),
        "wu": dense_init(ks[1], d, f, dt),
        "wd": dense_init(ks[2], f, d, dt),
    }


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    ct = cdtype(cfg)
    x = x.astype(ct)
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(ct))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(ct))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(ct) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(ct))


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity-bounded einsum dispatch)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> dict:
    dt = pdtype(cfg)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "wg": _uniform(ks[1], (e, d, f), scale, dt),
        "wu": _uniform(ks[2], (e, d, f), scale, dt),
        "wd": _uniform(ks[3], (e, f, d), 1.0 / math.sqrt(f), dt),
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(cfg, ks[4], cfg.moe_d_ff)
    return p


def moe_forward(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    capacity_factor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k routing with per-(batch-row) expert capacity.

    Returns (y, aux_loss). Dispatch/combine are one-hot einsums (GShard
    pattern) — TPU-friendly: everything is dense matmul on the MXU and the
    (B, S, E, C) dispatch tensor shards over E on the model axis.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    C = max(K, int(math.ceil(K * S * cf / E)))
    ct = cdtype(cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E) fp32
    gate_vals, gate_idx = lax.top_k(probs, K)  # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # expert one-hot over the K choices: (B,S,K,E)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # position of each (token, choice) in its expert's buffer, counting over
    # (s, k) in order: cumulative sum over flattened (S*K) per batch row.
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (B, S*K, E) position before me
    pos = pos.reshape(B, S, K, E)
    in_cap = (pos < C) & (onehot > 0)
    pos_c = jnp.sum(pos * onehot, axis=-1)  # (B,S,K) my slot id
    kept = jnp.any(in_cap, axis=-1)  # (B,S,K)

    # dispatch: (B,S,E,C) — built in compute dtype: the (B,S,E,C) tensors are
    # the largest MoE intermediates and bf16 halves their HBM traffic
    # (EXPERIMENTS.md §Perf jamba iteration); routing decisions (top-k,
    # positions) stay fp32/int above.
    cap_onehot = jax.nn.one_hot(pos_c, C, dtype=ct) * kept[..., None].astype(ct)
    onehot_ct = onehot.astype(ct)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot_ct, cap_onehot)
    combine = jnp.einsum(
        "bske,bskc,bsk->bsec", onehot_ct, cap_onehot, gate_vals.astype(ct)
    )

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x.astype(ct))
    g = jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(ct))
    u = jnp.einsum("ebcd,edf->ebcf", xin, p["wu"].astype(ct))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(ct) * u
    eout = jnp.einsum("ebcf,efd->ebcd", h, p["wd"].astype(ct))
    y = jnp.einsum("bsec,ebcd->bsd", combine, eout)

    if cfg.moe_shared_expert:
        y = y + mlp_forward(cfg, p["shared"], x)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))  # (E,) fraction routed
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) / K
    return y.astype(x.dtype), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(cfg: ModelConfig, key) -> dict:
    dt = pdtype(cfg)
    ks = jax.random.split(key, 2)
    p = {"embedding": _uniform(ks[0], (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(cdtype(cfg))


def unembed(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    ct = cdtype(cfg)
    if cfg.tie_embeddings:
        w = p["embedding"].astype(ct).T
    else:
        w = p["lm_head"].astype(ct)
    return jnp.einsum("bsd,dv->bsv", x.astype(ct), w).astype(jnp.float32)
