"""Mamba2 (SSD — state-space duality) blocks.

Train/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is split into chunks; within a chunk the recurrence is expanded into
a dense (MXU-friendly) quadratic form, and a cheap recurrence carries state
across chunks. Decode is the O(1) recurrent step. The pure-jnp chunked scan
here is also the oracle for ``repro.kernels.ssd_scan``.

Shapes (ngroups = 1, i.e. B/C shared across heads, MQA-style):
  x:  (B, S, H, P)      dt: (B, S, H)      A: (H,)
  Bm: (B, S, N)         Cm: (B, S, N)      state: (B, H, P, N)
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype, rms_norm


def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j < k <= i} x[..., k].

    x: (..., T) -> (..., T, T), -inf above the diagonal.
    """
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_(j, i] = cs[i] - cs[j]
    ii = jnp.arange(T)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (already softplus'd, > 0)
    A: jax.Array,   # (H,)       (negative)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # dt=0 padding is state-neutral: decay=exp(0)=1, update=0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_out, S = S, S + pad
    nc = S // chunk
    f32 = jnp.float32

    xb = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(Bsz, nc, chunk, H, P)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(Bsz, nc, chunk, H)  # (B,c,l,H)
    Bc = Bm.astype(f32).reshape(Bsz, nc, chunk, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, chunk, N)

    dA_cs = jnp.cumsum(dA, axis=2)  # (B,c,l,H) inclusive cumsum within chunk
    # --- intra-chunk (diagonal blocks): quadratic attention-like form
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B,c,H,l,l)
    CB = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (B,c,l,s)
    M = CB[:, :, None] * L  # (B,c,H,l,s)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", M, xb)

    # --- chunk-final states: S_c = sum_s B_s x_s * exp(dA_end - dA_cs_s)
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,c,l,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xb)

    # --- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (B,c,H)
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, P, N), f32)
    )

    def carry_fn(s_prev, xs):
        st, dec = xs  # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    final_state, prev_states = lax.scan(
        carry_fn,
        s0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)  # (B,c,H,P,N) state entering chunk

    # --- inter-chunk contribution: C_l · state_in · exp(dA_cs_l)
    state_decay = jnp.exp(dA_cs)  # (B,c,l,H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S_out]
    return y.astype(x.dtype), final_state


def ssd_step_ref(
    state: jax.Array,  # (B, H, P, N)
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    A: jax.Array,      # (H,)
    Bm: jax.Array,     # (B, N)
    Cm: jax.Array,     # (B, N)
) -> Tuple[jax.Array, jax.Array]:
    """Recurrent decode step. Returns (y (B,H,P), new_state)."""
    f32 = jnp.float32
    dtf = dt.astype(f32)
    decay = jnp.exp(dtf * A.astype(f32))  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x.astype(f32) * dtf[..., None], Bm.astype(f32))
    new_state = state.astype(f32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------


def init_mamba(cfg: ModelConfig, key) -> dict:
    dt = pdtype(cfg)
    d, di, ns, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    p = {
        "in_proj": dense_init(ks[0], d, proj_out, dt),
        "conv_w": _conv_init(ks[1], cfg.ssm_conv, cfg.ssm_conv_dim, dt),
        "conv_b": jnp.zeros((cfg.ssm_conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01))).astype(jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], di, d, dt),
    }
    return p


def _conv_init(key, width, dim, dtype):
    scale = 1.0 / math.sqrt(width)
    return jax.random.uniform(key, (width, dim), jnp.float32, -scale, scale).astype(dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    z, xc, dt_raw = jnp.split(zxbcdt, [di, di + di + 2 * ns], axis=-1)
    return z, xc, dt_raw  # xc = [x, B, C] (conv'd together)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B, S, C), w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):  # W = 4: tiny static unroll
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def mamba_forward(
    cfg: ModelConfig,
    p: dict,
    xin: jax.Array,  # (B, S, D)
    *,
    initial_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Full-sequence Mamba2 block (train / prefill)."""
    B, S, _ = xin.shape
    di, ns, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    ct = cdtype(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", xin.astype(ct), p["in_proj"].astype(ct))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_tail = xbc[:, S - (cfg.ssm_conv - 1):]  # pre-conv tail -> decode conv state
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    xh = x.reshape(B, S, nh, hp)
    y, final_state = ssd_chunked_ref(
        xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, initial_state=initial_state
    )
    y = y + x.reshape(B, S, nh, hp) * p["D"][:, None].astype(jnp.float32)
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.rmsnorm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(ct), p["out_proj"].astype(ct))
    if return_state:
        return out, final_state, conv_tail
    return out


def mamba_decode(
    cfg: ModelConfig,
    p: dict,
    xin: jax.Array,        # (B, 1, D)
    ssm_state: jax.Array,  # (B, H, P, N)
    conv_state: jax.Array, # (B, W-1, conv_dim)
):
    """One-token recurrent step; returns (out (B,1,D), ssm_state, conv_state)."""
    B = xin.shape[0]
    di, ns, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    W = cfg.ssm_conv
    ct = cdtype(cfg)
    zxbcdt = jnp.einsum("bd,de->be", xin[:, 0].astype(ct), p["in_proj"].astype(ct))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    # roll conv state, apply conv at last position
    full = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B, W, C)
    new_conv_state = full[:, 1:]
    conv_out = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(xbc.dtype)
    x, Bm, Cm = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, nh, hp)
    y, new_ssm = ssd_step_ref(ssm_state, xh, dt, A, Bm, Cm)
    y = y + xh * p["D"][:, None].astype(jnp.float32)
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.rmsnorm_eps)
    out = jnp.einsum("be,ed->bd", y.astype(ct), p["out_proj"].astype(ct))
    return out[:, None, :], new_ssm, new_conv_state
