"""Backend-dispatching jit'd wrappers for the Pallas kernels.

``use_pallas='auto'`` selects the Pallas kernel on TPU and the pure-jnp
reference elsewhere (Pallas does not lower to the CPU host platform; the
dry-run therefore analyses the reference HLO — conservative for the paths
we hand-optimize). ``use_pallas=True`` with ``interpret=True`` runs the
kernel body in Python on CPU — how the tests validate it.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas) -> Tuple[bool, bool]:
    """-> (use_kernel, interpret)."""
    if use_pallas == "auto":
        return (_on_tpu(), False)
    if use_pallas == "interpret":
        return (True, True)
    return (bool(use_pallas), not _on_tpu())


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _fa.flash_attention(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=interp,
        )
    return _ref.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k=1024,
                     use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _dec.decode_attention(
            q, k_cache, v_cache, lengths, block_k=block_k, interpret=interp
        )
    return _ref.decode_attention(q, k_cache, v_cache, lengths, block_k=block_k)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, initial_state=None,
             use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use and initial_state is None:
        return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interp)
    return _ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         initial_state=initial_state)
