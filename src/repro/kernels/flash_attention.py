"""Pallas TPU flash attention (causal, GQA) — the prefill/train hot spot.

Design (TPU-native, not a CUDA port):
* grid = (batch, kv_heads, n_q_blocks, n_k_blocks); the k dimension is the
  innermost, *sequential* ("arbitrary") axis so the online-softmax state
  (m, l, acc) lives in VMEM scratch across k iterations — the TPU analogue
  of a CUDA persistent-CTA loop;
* GQA is handled by giving each kv-head program its whole q-head *group*
  (block shape (G*block_q, d)) so the MXU contracts (G*bq, d) x (d, bk) —
  groups ride the sublane dimension, no head replication;
* causal blocks above the diagonal are skipped with ``pl.when`` (no MXU
  work issued), giving the exact triangular FLOP count;
* fp32 accumulation; bf16 (or input dtype) output.

Block sizes default to (512, 512): VMEM for one program =
q (G*512*128*2B) + k/v (2*512*128*2B) + acc (G*512*128*4B) ~= 1.8 MiB at
G=8 — comfortably inside the ~16 MiB VMEM budget with double buffering.

Validated in interpret mode against ``repro.models.layers.flash_attention_
ref`` (itself validated against plain softmax attention) — see
tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,                # output
    m_scr, l_scr, acc_scr,  # VMEM scratch
    *,
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
    seq_q: int,
    seq_k: int,
    groups: int,
):
    b, h, qi, ki = (pl.program_id(i) for i in range(4))
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: process block only if some q position >= some k position
    run = True
    if causal:
        run = (qi + 1) * block_q - 1 >= ki * block_k

    @pl.when(run)
    def _body():
        q = q_ref[...].reshape(groups * block_q, -1)  # (G*bq, d)
        k = k_ref[0, 0]                               # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (G*bq, bk)
        # mask: causal + kv validity (padding). The q position repeats per
        # GQA group along the fused (G*bq) sublane axis.
        q_pos = (
            qi * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (groups, block_q, block_k), 1)
        ).reshape(groups * block_q, block_k)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (groups * block_q, block_k), 1
        )
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _fin():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qg = q.reshape(B, Sq, Hkv, G, Dh).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sq,Dh)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    kt = k.transpose(0, 2, 1, 3)  # (B,Hkv,Sk,Dh)
    vt = v.transpose(0, 2, 1, 3)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (Sq + pad_q) // block_q
    n_k = (Sk + pad_k) // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, block_q=block_q, block_k=block_k, causal=causal,
        seq_q=Sq, seq_k=Sk, groups=G,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, Dh), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, block_q, Dh), lambda b, h, i, j: (b, h, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, Dh), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, kt, vt)
    out = out.transpose(0, 3, 1, 2, 4)[:, :Sq]  # (B,Sq,Hkv,G,Dh)
    return out.reshape(B, Sq, Hq, Dh)
