"""Pure-jnp oracles for every kernel (re-exported from the model layers so
the kernels are validated against exactly the math the models run)."""
from __future__ import annotations

from repro.models.layers import decode_attention_ref as decode_attention  # noqa: F401
from repro.models.layers import flash_attention_ref as flash_attention  # noqa: F401
from repro.models.mamba2 import ssd_chunked_ref as ssd_scan  # noqa: F401
