"""Pallas TPU flash-decode: one new token vs a long KV cache.

Decode at 32k-500k context is HBM-bandwidth-bound on the KV reads, so the
kernel's job is to stream K/V blocks through VMEM exactly once with online
softmax, keeping the (tiny) q resident:

* grid = (batch, kv_heads, n_k_blocks), k innermost/sequential; scratch
  holds (G, 1) running max/denominator and the (G, Dh) accumulator;
* per-sequence cache lengths mask invalid positions (continuous batching);
* the GQA group dimension rides the sublane axis of the MXU: the score
  matmul is (G, Dh) x (Dh, block_k).

Oracle: ``repro.models.layers.decode_attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,              # scalar prefetch: (B,) lengths
    q_ref, k_ref, v_ref,  # inputs
    o_ref,                # output
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_k: int,
    groups: int,
):
    b, h, ki = (pl.program_id(i) for i in range(3))
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(ki * block_k < length)
    def _body():
        q = q_ref[0, 0]  # (G, Dh)
        k = k_ref[0, 0]  # (bk, Dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bk)
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, Dh)
    k_cache: jax.Array,  # (B, L, Hkv, Dh)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,) int32 valid lengths
    *,
    block_k: int = 1024,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    B, _, Hq, Dh = q.shape
    _, L, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    block_k = min(block_k, L)
    pad = (-L) % block_k
    kt = k_cache.transpose(0, 2, 1, 3)  # (B,Hkv,L,Dh)
    vt = v_cache.transpose(0, 2, 1, 3)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_k = (L + pad) // block_k
    qg = q.reshape(B, 1, Hkv, G, Dh).transpose(0, 2, 3, 1, 4)[..., 0, :]  # (B,Hkv,G,Dh)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, groups=G
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, j, lens: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, j, lens: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, Hkv, G, 1, Dh).transpose(0, 3, 1, 2, 4).reshape(B, 1, Hq, Dh)
