"""Pallas TPU chunked SSD scan (Mamba2 / jamba hot loop).

The SSD duality lets the selective-scan be computed as dense chunk-local
matmuls (MXU work) plus a tiny cross-chunk recurrence. The kernel maps
chunks to the innermost *sequential* grid axis and carries the (P, N) state
in VMEM scratch — the recurrence never touches HBM:

  grid = (batch, heads, n_chunks)
  per chunk:  L = exp(segsum(dtA))           (chunk, chunk) fp32
              y_diag = ((C B^T) * L) @ (x*dt)           intra-chunk, MXU
              y_off  = (C @ state_in) * exp(cumsum dtA) inter-chunk
              state  = state * exp(sum dtA) + (B * decay)^T @ (x*dt)

B/C are head-shared (ngroups=1, MQA-style) so their blocks are indexed
ignoring the head axis. Oracle: ``repro.models.mamba2.ssd_chunked_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(x: jax.Array) -> jax.Array:
    """x: (T,) -> (T, T) lower-tri segment sums, -inf above diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    return jnp.where(ii >= jj, diff, -jnp.inf)


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,  # inputs
    y_ref, fs_ref,                       # outputs: y, final state
    state_scr,                           # VMEM scratch: (P, N) fp32
    *,
    chunk: int,
):
    b, h, ci = (pl.program_id(i) for i in range(3))
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (chunk, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)   # (chunk,)
    A = a_ref[0]                               # scalar for this head
    Bm = b_ref[0].astype(jnp.float32)          # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)          # (chunk, N)

    xdt = x * dt[:, None]
    dA = dt * A                                # (chunk,)
    dA_cs = jnp.cumsum(dA)                     # inclusive
    L = jnp.exp(_segsum(dA))                   # (chunk, chunk)

    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (chunk, chunk)
    y_diag = jax.lax.dot_general(
        CB * L, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (chunk, P)

    state_in = state_scr[...]                  # (P, N)
    y_off = jax.lax.dot_general(
        Cm, state_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(dA_cs)[:, None]                # (chunk, P)

    y_ref[...] = (y_diag + y_off).reshape(y_ref.shape).astype(y_ref.dtype)

    decay_states = jnp.exp(dA_cs[-1] - dA_cs)  # (chunk,)
    upd = jax.lax.dot_general(
        xdt, Bm * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (P, N)
    state_scr[...] = state_in * jnp.exp(dA_cs[-1]) + upd

    @pl.when(ci == n_c - 1)
    def _fin():
        fs_ref[...] = state_scr[...].reshape(fs_ref.shape)


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) (softplus'd)
    A: jax.Array,   # (H,) negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # dt=0 padding is state-neutral
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    n_c = Sp // chunk
    xt = x.transpose(0, 2, 1, 3)  # (B,H,S,P)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, fs = pl.pallas_call(
        kernel,
        grid=(Bsz, H, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, dt, A.astype(jnp.float32), Bm, Cm)
    y = y.transpose(0, 2, 1, 3)[:, :S]
    return y.astype(x.dtype), fs
